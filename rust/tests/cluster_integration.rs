//! Cluster-mode integration: the replicated front end is just another
//! `FilterApi` transport. The UNMODIFIED acceptance driver from
//! `tests/common/` runs over a three-server fleet with R=2 and must
//! produce bit-identical answers and identical typed errors to the
//! in-process service; on top of that, replica failure is transparent
//! (reads fail over, writes keep acking), a rejoining replica is
//! re-seeded by snapshot shipping, and a fully dead replica set answers
//! with the typed `NoQuorum` — never a hang.

use std::net::TcpListener;
use std::sync::Arc;

use gbf::coordinator::{
    ClusterConfig, ClusterFilterService, FilterService, GbfError, RemoteFilterService, WireServer,
};
use gbf::workload::keygen::unique_keys;

mod common;
use common::{cfg, drive_api, scratch_dir, spec};

/// Boot `n` loopback wire servers, each with its own empty catalog.
fn fleet(n: usize) -> (Vec<WireServer>, Vec<String>) {
    let mut servers = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let service = Arc::new(FilterService::new());
        let server = WireServer::bind(service, "127.0.0.1:0").unwrap();
        addrs.push(server.local_addr().to_string());
        servers.push(server);
    }
    (servers, addrs)
}

#[test]
fn cluster_runs_the_unmodified_acceptance_driver() {
    // oracle: the same body over the in-process catalog
    let local = FilterService::new();
    let (local_hits, local_stats) = drive_api(&local);

    // the cluster front end: three servers, every namespace on two
    let (_servers, addrs) = fleet(3);
    let cluster = ClusterFilterService::connect(ClusterConfig::new(addrs, 2).unwrap()).unwrap();
    let (cluster_hits, cluster_stats) = drive_api(&cluster);

    // identical query answers — down to the false positives
    assert_eq!(local_hits, cluster_hits, "bit-identical answers through the cluster");
    // identical accounting on the preferred replica: every write fans
    // out and every read (and the stats call) lands on the same first
    // live replica, so the counters match the single-service run
    assert_eq!(local_stats.metrics.adds, cluster_stats.metrics.adds);
    assert_eq!(local_stats.metrics.queries, cluster_stats.metrics.queries);
    assert_eq!(local_stats.num_shards, cluster_stats.num_shards);
    assert_eq!(
        local_stats.shards.iter().map(|s| s.keys).sum::<u64>(),
        cluster_stats.shards.iter().map(|s| s.keys).sum::<u64>(),
        "per-shard key totals agree through the cluster"
    );
    assert_eq!(local_stats.backend, cluster_stats.backend);
}

#[test]
fn replication_fans_out_to_every_replica() {
    let (_servers, addrs) = fleet(3);
    let cluster =
        ClusterFilterService::connect(ClusterConfig::new(addrs.clone(), 2).unwrap()).unwrap();

    let h = cluster.create_filter_spec("fan", spec(13, 2, 1024, 150)).unwrap();
    let keys = unique_keys(4_000, 0xC0);
    h.add_bulk(&keys).wait().unwrap();

    // exactly R=2 servers hold the namespace, and each holds ALL keys
    let placed = cluster.config().placement("fan");
    assert_eq!(placed.len(), 2);
    let mut holders = 0;
    for (i, addr) in addrs.iter().enumerate() {
        let direct = RemoteFilterService::connect(addr.as_str()).unwrap();
        match direct.stats("fan") {
            Ok(stats) => {
                assert!(placed.contains(&i), "namespace on an unplaced server {i}");
                assert_eq!(stats.metrics.adds, 4_000, "replica {i} holds every write");
                holders += 1;
            }
            Err(GbfError::NoSuchFilter(_)) => {
                assert!(!placed.contains(&i), "placed replica {i} is missing the namespace");
            }
            Err(other) => panic!("direct stats on server {i}: {other:?}"),
        }
    }
    assert_eq!(holders, 2, "replication factor is respected");
}

#[test]
fn replica_failure_is_transparent_and_rejoin_reseeds() {
    // reserve an address for the replica that starts dark: bind an
    // ephemeral listener, note the port, release it unconnected (no
    // TIME_WAIT socket holds the port)
    let reserved = TcpListener::bind("127.0.0.1:0").unwrap();
    let dark_addr = reserved.local_addr().unwrap().to_string();
    drop(reserved);

    let live0 = Arc::new(FilterService::new());
    let server0 = WireServer::bind(Arc::clone(&live0), "127.0.0.1:0").unwrap();
    let (extra, extra_addrs) = fleet(1);
    let addrs =
        vec![server0.local_addr().to_string(), dark_addr.clone(), extra_addrs[0].clone()];

    let sync_dir = scratch_dir("cluster-sync");
    let mut config = ClusterConfig::new(addrs, 2)
        .unwrap()
        // preferred replica (index 1) starts dark; index 0 carries the load
        .with_override("ha", vec![1, 0])
        .unwrap();
    config.sync_dir = sync_dir.to_str().unwrap().to_string();
    let cluster = ClusterFilterService::connect(config).unwrap();

    // create + populate with the preferred replica down: create yields a
    // working handle from any live replica, writes ack there, reads fail
    // over — the caller never notices
    let h = cluster.create_filter_spec("ha", spec(13, 2, 1024, 150)).unwrap();
    let keys = unique_keys(5_000, 0xC1);
    h.add_bulk(&keys).wait().unwrap();
    let mut probe = keys.clone();
    probe.extend(unique_keys(2_500, 0xC2));
    let before = h.query_bulk(&probe).wait().unwrap();
    assert!(before[..5_000].iter().all(|&x| x), "no false negatives with a replica down");

    // the dark replica rejoins with an EMPTY catalog; reconcile ships a
    // snapshot from the surviving co-replica and warm-starts it
    let rejoined = Arc::new(FilterService::new());
    let server1 = WireServer::bind(Arc::clone(&rejoined), dark_addr.as_str()).unwrap();
    cluster.reconcile_now();
    assert_eq!(
        rejoined.stats("ha").unwrap().metrics.adds,
        5_000,
        "rejoined replica was re-seeded with every key"
    );

    // kill the OTHER replica mid-workload: the freshly re-seeded one
    // answers identically, and writes still ack
    let h2 = cluster.handle("ha").unwrap();
    drop(server0);
    let after = h2.query_bulk(&probe).wait().unwrap();
    assert_eq!(before, after, "failover preserves every answer, including false positives");
    h2.add(0xDEAD_BEEF).wait().unwrap();
    assert_eq!(cluster.stats("ha").unwrap().metrics.adds, 5_001);

    // kill the last replica: typed NoQuorum, not a hang
    drop(server1);
    match h2.query(keys[0]).wait() {
        Err(GbfError::NoQuorum { name, .. }) => assert_eq!(name, "ha"),
        other => panic!("expected NoQuorum with the whole replica set dead, got {other:?}"),
    }
    match cluster.stats("ha") {
        Err(GbfError::NoQuorum { name, replicas }) => {
            assert_eq!(name, "ha");
            assert_eq!(replicas, 2);
        }
        other => panic!("expected NoQuorum from stats, got {other:?}"),
    }
    std::fs::remove_dir_all(&sync_dir).ok();
}

#[test]
fn gateway_serves_unmodified_wire_clients() {
    // in-process oracle fed the same keys
    let oracle = FilterService::new();
    let oh = oracle.create_filter("gw", cfg(13), 2).unwrap();
    let keys = unique_keys(3_000, 0xC3);
    let mut probe = keys.clone();
    probe.extend(unique_keys(1_500, 0xC4));
    oh.add_bulk(&keys).wait().unwrap();
    let oracle_hits = oh.query_bulk(&probe).wait().unwrap();

    // the cluster itself sits behind a wire listener; a stock wire
    // client speaks to the fleet without knowing it is one
    let (_servers, addrs) = fleet(2);
    let cluster = ClusterFilterService::connect(ClusterConfig::new(addrs, 2).unwrap()).unwrap();
    let gateway = WireServer::bind_catalog(Arc::new(cluster), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(gateway.local_addr()).unwrap();

    let rh = client.create_filter("gw", cfg(13), 2).unwrap();
    rh.add_bulk(&keys).wait().unwrap();
    let via_gateway = rh.query_bulk(&probe).wait().unwrap();
    assert_eq!(oracle_hits, via_gateway, "identical answers through gateway + fleet");

    let stats = client.stats("gw").unwrap();
    assert_eq!(stats.metrics.adds, 3_000);
    assert_eq!(client.list_filters().unwrap(), vec!["gw".to_string()]);
    match client.stats("nope") {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "nope"),
        other => panic!("expected NoSuchFilter through the gateway, got {other:?}"),
    }
    client.drop_filter("gw").unwrap();
    assert!(client.list_filters().unwrap().is_empty());
}
