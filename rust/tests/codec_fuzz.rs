//! Wire-codec fuzzing (ISSUE 6 tentpole leg 3): the frame reader and the
//! request/response decoders against the committed regression corpus and
//! a deterministic seeded mutation sweep.
//!
//! The property is uniform: every decode entry point returns `Ok` or a
//! *typed* error on arbitrary bytes — it never panics and never honours a
//! hostile length prefix with a giant allocation. Accepted mutants must
//! additionally re-encode stably (decode → encode → decode is a fixed
//! point), so the fuzzer also guards codec canonicalization.
//!
//! Corpus layout (`rust/corpus/wire/*.hex`, see `infra::fuzz::parse_hex`):
//! * `frame-*` — whole frames (length prefix + payload) for `read_frame`
//! * `resp-*`  — response payloads for `decode_response`
//! * others    — request payloads for `decode_request`
//!
//! Seeded sweeps replay identically per seed; override with
//! `GBF_FUZZ_SEED` / `GBF_FUZZ_ITERS` to widen a local hunt.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use gbf::coordinator::wire::codec::{
    decode_request, decode_response, encode_request, encode_response, read_frame, write_frame, Request, Response,
};
use gbf::coordinator::{BatchPolicy, FilterService, FilterSpec, GbfError, Ledger, LedgerEntry};
use gbf::filter::params::FilterConfig;
use gbf::infra::fuzz::{corpus_dir, load_corpus, Mutator};

fn wire_corpus() -> Vec<(String, Vec<u8>)> {
    load_corpus(&corpus_dir("wire"))
        .expect("wire corpus present")
        .into_iter()
        .map(|(path, bytes)| {
            let name = path.file_name().expect("file name").to_string_lossy().into_owned();
            (name, bytes)
        })
        .collect()
}

fn entry(corpus: &[(String, Vec<u8>)], name: &str) -> Vec<u8> {
    corpus
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("corpus entry {name} missing"))
        .1
        .clone()
}

/// Run one corpus entry through the decoder its filename selects.
fn replay(name: &str, bytes: &[u8]) -> Result<(), String> {
    if name.starts_with("frame-") {
        read_frame(&mut &bytes[..]).map(|_| ()).map_err(|e| format!("{e:#}"))
    } else if name.starts_with("resp-") {
        decode_response(bytes).map(|_| ()).map_err(|e| format!("{e:#}"))
    } else {
        decode_request(bytes).map(|_| ()).map_err(|e| format!("{e:#}"))
    }
}

fn small_spec(max_batch: usize) -> FilterSpec {
    FilterSpec {
        config: FilterConfig { log2_m_words: 12, ..Default::default() },
        shards: 1,
        policy: BatchPolicy { max_batch, max_wait: Duration::from_micros(200) },
        max_queue_depth: None,
    }
}

fn small_ledger() -> Ledger {
    Ledger::from_parts(
        3,
        vec![
            ("dead".into(), LedgerEntry { epoch: 2, tombstone: true }),
            ("live".into(), LedgerEntry { epoch: 1, tombstone: false }),
        ],
    )
}

fn valid_requests() -> Vec<Vec<u8>> {
    let reqs = [
        Request::List,
        Request::Ping,
        Request::Create { name: "ns".into(), spec: small_spec(1024) },
        Request::Drop { name: "ns".into() },
        Request::Stats { name: "ns".into() },
        Request::AddBulk { name: "ns".into(), instance: 7, keys: vec![1, 2, 3, u64::MAX] },
        Request::QueryBulk { name: "ns".into(), instance: 7, keys: vec![9, 10] },
        Request::Snapshot { name: "ns".into(), dir: "snapshots/a".into() },
        Request::Restore { name: "ns".into(), dir: "snapshots/a".into() },
        Request::LedgerSync { ledger: small_ledger() },
        Request::Stamp { name: "ns".into(), instance: 7, epoch: 2 },
        Request::Digest { name: "ns".into() },
        Request::ClusterAdmin { add: true, addr: "127.0.0.1:7070".into() },
    ];
    reqs.iter().enumerate().map(|(i, r)| encode_request(i as u64, r)).collect()
}

fn valid_responses() -> Vec<Vec<u8>> {
    let resps = [
        Response::Ok,
        Response::Created { instance: 3 },
        Response::Names(vec!["a".into(), "b".into()]),
        Response::Err(GbfError::Overloaded { name: "ns".into(), depth: 12 }),
        Response::Err(GbfError::SnapshotVersion { found: 9, supported: 1 }),
        Response::Err(GbfError::NoQuorum { name: "ns".into(), replicas: 2 }),
        Response::Err(GbfError::StaleEpoch { name: "ns".into(), held: 5, proposed: 2 }),
        Response::Err(GbfError::NotSupported("cluster-admin".into())),
        Response::Err(GbfError::DeadlineExceeded { op: "add_bulk".into(), elapsed_ms: 1500 }),
        Response::Ledger { ledger: small_ledger(), bindings: vec![("live".into(), 1)] },
        Response::Digest(vec![0xDEAD_BEEF, 1]),
    ];
    resps.iter().enumerate().map(|(i, r)| encode_response(i as u64, r)).collect()
}

fn fuzz_seed() -> u64 {
    std::env::var("GBF_FUZZ_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x00C0_FFEE)
}

fn fuzz_iters() -> u64 {
    std::env::var("GBF_FUZZ_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(2_000)
}

#[test]
fn corpus_replay_never_panics() {
    let corpus = wire_corpus();
    assert!(corpus.len() >= 10, "wire corpus unexpectedly small: {}", corpus.len());
    for (name, bytes) in &corpus {
        let outcome = catch_unwind(AssertUnwindSafe(|| replay(name, bytes)));
        assert!(outcome.is_ok(), "corpus entry {name} panicked the decoder");
    }
}

#[test]
fn valid_corpus_entries_decode() {
    let corpus = wire_corpus();
    let (_, req) = decode_request(&entry(&corpus, "valid-list.hex")).expect("valid-list decodes");
    assert!(matches!(req, Request::List));
    let (id, req) = decode_request(&entry(&corpus, "valid-ping.hex")).expect("valid-ping decodes");
    assert_eq!(id, 12);
    assert!(matches!(req, Request::Ping));
    let (_, req) = decode_request(&entry(&corpus, "valid-create.hex")).expect("valid-create decodes");
    match req {
        Request::Create { name, spec } => {
            assert_eq!(name, "ns");
            assert_eq!(spec.policy.max_batch, 1024);
        }
        other => panic!("valid-create decoded as {other:?}"),
    }
    let (_, req) = decode_request(&entry(&corpus, "valid-query.hex")).expect("valid-query decodes");
    match req {
        Request::QueryBulk { instance, keys, .. } => {
            assert_eq!(instance, 7);
            assert_eq!(keys, vec![1, 2, 3]);
        }
        other => panic!("valid-query decoded as {other:?}"),
    }
    let (_, resp) = decode_response(&entry(&corpus, "resp-valid-ok.hex")).expect("resp-valid-ok decodes");
    assert!(matches!(resp, Response::Ok));
}

#[test]
fn snapshot_restore_corpus_entries_decode() {
    let corpus = wire_corpus();
    let (id, req) = decode_request(&entry(&corpus, "valid-snapshot.hex")).expect("valid-snapshot decodes");
    assert_eq!(id, 10);
    match req {
        Request::Snapshot { name, dir } => {
            assert_eq!(name, "ns");
            assert_eq!(dir, "snaps/ns");
        }
        other => panic!("valid-snapshot decoded as {other:?}"),
    }
    let (id, req) = decode_request(&entry(&corpus, "valid-restore.hex")).expect("valid-restore decodes");
    assert_eq!(id, 11);
    match req {
        Request::Restore { name, dir } => {
            assert_eq!(name, "ns");
            assert_eq!(dir, "snaps/ns");
        }
        other => panic!("valid-restore decoded as {other:?}"),
    }
    // The codec treats snapshot paths as opaque strings (they resolve
    // server-side): a traversal-looking dir DECODES — refusing it is the
    // server's call, and this pin keeps the codec from silently
    // rewriting or rejecting paths behind the server's back.
    let (_, req) = decode_request(&entry(&corpus, "snapshot-path-escape.hex")).expect("path-escape decodes");
    match req {
        Request::Snapshot { dir, .. } => assert_eq!(dir, "../../etc", "path carried verbatim"),
        other => panic!("snapshot-path-escape decoded as {other:?}"),
    }
}

/// Error byte 12 (ISSUE 10): the committed corpus pins the
/// `DeadlineExceeded` wire layout — err tag `0x0c`, op-name string,
/// `elapsed_ms` u64 — so a codec change that silently renumbers or
/// reshapes it fails here, not in a cross-version fleet.
#[test]
fn deadline_error_corpus_entry_decodes() {
    let corpus = wire_corpus();
    let (id, resp) = decode_response(&entry(&corpus, "resp-valid-err-deadline.hex"))
        .expect("resp-valid-err-deadline decodes");
    assert_eq!(id, 15);
    match resp {
        Response::Err(GbfError::DeadlineExceeded { op, elapsed_ms }) => {
            assert_eq!((op.as_str(), elapsed_ms), ("add_bulk", 1500));
        }
        other => panic!("resp-valid-err-deadline decoded as {other:?}"),
    }
}

#[test]
fn hostile_corpus_entries_fail_typed() {
    let corpus = wire_corpus();
    for name in [
        "truncated-query.hex",
        "trailing-garbage.hex",
        "unknown-tag.hex",
        "bad-version.hex",
        "keys-length-lie.hex",
        "truncated-restore-path.hex",
        "snapshot-name-oversize.hex",
        "ping-trailing-garbage.hex",
        "ledger-bad-tombstone.hex",
        "ledger-count-lie.hex",
        "cluster-admin-bad-op.hex",
        "stamp-truncated.hex",
    ] {
        assert!(decode_request(&entry(&corpus, name)).is_err(), "{name} must be a typed decode error");
    }
    for name in ["resp-names-count-lie.hex", "resp-err-truncated.hex", "resp-deadline-truncated.hex"] {
        assert!(decode_response(&entry(&corpus, name)).is_err(), "{name} must be a typed decode error");
    }
    for name in ["frame-oversize-lie.hex", "frame-truncated.hex"] {
        let bytes = entry(&corpus, name);
        assert!(read_frame(&mut &bytes[..]).is_err(), "{name} must be a typed frame error");
    }
}

#[test]
fn cluster_corpus_entries_decode() {
    let corpus = wire_corpus();
    let (id, req) = decode_request(&entry(&corpus, "valid-ledger-sync.hex")).expect("ledger-sync decodes");
    assert_eq!(id, 13);
    match req {
        Request::LedgerSync { ledger } => {
            assert_eq!(ledger.next_epoch(), 3);
            assert!(ledger.is_tombstoned("dead"));
            assert!(!ledger.is_tombstoned("live"));
        }
        other => panic!("valid-ledger-sync decoded as {other:?}"),
    }
    let (id, req) = decode_request(&entry(&corpus, "valid-stamp.hex")).expect("stamp decodes");
    assert_eq!(id, 14);
    match req {
        Request::Stamp { name, instance, epoch } => {
            assert_eq!((name.as_str(), instance, epoch), ("ns", 7, 2));
        }
        other => panic!("valid-stamp decoded as {other:?}"),
    }
    let (_, req) = decode_request(&entry(&corpus, "valid-digest.hex")).expect("digest decodes");
    assert!(matches!(req, Request::Digest { ref name } if name == "ns"));
    let (_, req) = decode_request(&entry(&corpus, "valid-cluster-admin.hex")).expect("cluster-admin decodes");
    match req {
        Request::ClusterAdmin { add, addr } => {
            assert!(add);
            assert_eq!(addr, "127.0.0.1:7070");
        }
        other => panic!("valid-cluster-admin decoded as {other:?}"),
    }
    let (_, resp) = decode_response(&entry(&corpus, "resp-valid-ledger.hex")).expect("ledger response decodes");
    match resp {
        Response::Ledger { ledger, bindings } => {
            assert_eq!(ledger.next_epoch(), 2);
            assert!(!ledger.is_tombstoned("ns"));
            assert_eq!(bindings, vec![("ns".to_string(), 1)]);
        }
        other => panic!("resp-valid-ledger decoded as {other:?}"),
    }
    let (_, resp) = decode_response(&entry(&corpus, "resp-valid-digest.hex")).expect("digest response decodes");
    assert!(matches!(resp, Response::Digest(ref d) if d == &[0xDEAD_BEEF, 1]));
}

/// Regression (fuzzer finding): a hostile Create carrying
/// `policy.max_batch = 0` decodes cleanly — the codec is transparent — but
/// the service must refuse it with a typed `InvalidConfig` instead of
/// handing the batch worker a policy that can never drain the queue.
#[test]
fn max_batch_zero_create_is_refused_at_service() {
    let corpus = wire_corpus();
    let (_, req) = decode_request(&entry(&corpus, "create-max-batch-zero.hex")).expect("hostile create decodes");
    let spec = match req {
        Request::Create { spec, .. } => spec,
        other => panic!("expected Create, decoded {other:?}"),
    };
    assert_eq!(spec.policy.max_batch, 0, "corpus entry must carry the hostile policy");
    let svc = FilterService::new();
    match svc.create_filter_spec("hostile", spec) {
        Err(GbfError::InvalidConfig(msg)) => assert!(msg.contains("max_batch"), "{msg}"),
        Err(other) => panic!("hostile spec must be InvalidConfig, got {other:?}"),
        Ok(_) => panic!("hostile spec must be refused, but a namespace was created"),
    }
}

#[test]
fn mutation_sweep_requests_and_responses() {
    let seed = fuzz_seed();
    let iters = fuzz_iters();
    let reqs = valid_requests();
    let resps = valid_responses();
    let mut m = Mutator::new(seed);
    for i in 0..iters {
        let a = &reqs[(i % reqs.len() as u64) as usize];
        let b = &reqs[((i / 3) % reqs.len() as u64) as usize];
        let mutant = m.mutate(a, b);
        let outcome = catch_unwind(AssertUnwindSafe(|| decode_request(&mutant)));
        let decoded = outcome.unwrap_or_else(|_| {
            panic!("decode_request panicked (seed {seed}, iter {i}): {}", hex(&mutant));
        });
        if let Ok((id, req)) = decoded {
            let reencoded = encode_request(id, &req);
            let (id2, req2) = decode_request(&reencoded).unwrap_or_else(|e| {
                panic!("accepted mutant failed to re-decode (seed {seed}, iter {i}): {e:#}");
            });
            assert_eq!(id, id2);
            assert_eq!(format!("{req:?}"), format!("{req2:?}"), "seed {seed}, iter {i}");
        }

        let a = &resps[(i % resps.len() as u64) as usize];
        let b = &resps[((i / 5) % resps.len() as u64) as usize];
        let mutant = m.mutate(a, b);
        let outcome = catch_unwind(AssertUnwindSafe(|| decode_response(&mutant)));
        let decoded = outcome.unwrap_or_else(|_| {
            panic!("decode_response panicked (seed {seed}, iter {i}): {}", hex(&mutant));
        });
        if let Ok((id, resp)) = decoded {
            let reencoded = encode_response(id, &resp);
            let (id2, resp2) = decode_response(&reencoded).unwrap_or_else(|e| {
                panic!("accepted mutant failed to re-decode (seed {seed}, iter {i}): {e:#}");
            });
            assert_eq!(id, id2);
            assert_eq!(format!("{resp:?}"), format!("{resp2:?}"), "seed {seed}, iter {i}");
        }
    }
}

#[test]
fn frame_mutation_sweep() {
    let seed = fuzz_seed() ^ 0xF4A3;
    let iters = fuzz_iters();
    let mut framed = Vec::new();
    for payload in valid_requests() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).expect("vec write");
        framed.push(buf);
    }
    let mut m = Mutator::new(seed);
    for i in 0..iters {
        let a = &framed[(i % framed.len() as u64) as usize];
        let b = &framed[((i / 7) % framed.len() as u64) as usize];
        let mutant = m.mutate(a, b);
        let outcome = catch_unwind(AssertUnwindSafe(|| read_frame(&mut &mutant[..]).map(|_| ())));
        assert!(outcome.is_ok(), "read_frame panicked (seed {seed}, iter {i}): {}", hex(&mutant));
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect::<Vec<_>>().join(" ")
}
