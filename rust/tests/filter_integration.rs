//! Cross-module filter integration: variants x engine x analytics.

use gbf::analytics::fpr::{measure_fpr, measure_fpr_space_optimal};
use gbf::filter::params::{fpr_min, space_optimal_n, FilterConfig, Scheme, Variant};
use gbf::filter::{AnyBloom, Bloom};
use gbf::workload::keygen::{disjoint_key_sets, resample, unique_keys};

fn every_variant(m: u32) -> Vec<FilterConfig> {
    vec![
        FilterConfig { variant: Variant::Sbf, block_bits: 256, k: 16, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Sbf, block_bits: 512, k: 8, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Sbf, block_bits: 1024, k: 16, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Rbbf, block_bits: 64, k: 16, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Csbf, block_bits: 512, k: 16, z: 2, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Csbf, block_bits: 1024, k: 16, z: 4, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Bbf, block_bits: 256, k: 16, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Bbf, block_bits: 256, k: 16, scheme: Scheme::Iter, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Cbf, k: 16, log2_m_words: m, ..Default::default() },
        FilterConfig { variant: Variant::Sbf, block_bits: 128, word_bits: 32, k: 8, log2_m_words: m, ..Default::default() },
    ]
}

#[test]
fn lifecycle_every_variant() {
    for cfg in every_variant(14) {
        let filter = AnyBloom::new(cfg).unwrap();
        let (ins, qry) = disjoint_key_sets(20_000, 20_000, 1);
        filter.bulk_add(&ins, 0);
        // contract: no false negatives
        assert!(filter.bulk_contains(&ins, 0).iter().all(|&h| h), "{}", cfg.name());
        // resampled lookups (true-positive benchmark shape, §5.1)
        let hot = resample(&ins, 10_000, 2);
        assert!(filter.bulk_contains(&hot, 0).iter().all(|&h| h));
        // false positives exist but are bounded
        let fp = filter.bulk_contains(&qry, 0).iter().filter(|&&h| h).count();
        assert!(fp < 2_000, "{}: fp={fp}", cfg.name());
        // clear resets
        filter.clear();
        assert!(!filter.bulk_contains(&ins[..100], 0).iter().any(|&h| h));
    }
}

#[test]
fn fpr_respects_space_optimal_floor() {
    // At the space-optimal load no variant can beat fpr_min(c) (Eq. 3);
    // blocked variants sit above it, CBF close to it.
    let m = 14u32;
    for cfg in every_variant(m) {
        if cfg.word_bits != 64 {
            continue;
        }
        let c_bits = cfg.m_bits() as f64 / space_optimal_n(cfg.m_bits(), cfg.k) as f64;
        let floor = fpr_min(c_bits);
        let rep = measure_fpr_space_optimal(&cfg, 100_000, 3).unwrap();
        assert!(
            rep.fpr >= floor * 0.5 - 1e-7,
            "{}: measured {} below Eq.(3) floor {}",
            cfg.name(),
            rep.fpr,
            floor
        );
        assert!(rep.fpr < 0.1, "{}: unusably high fpr {}", cfg.name(), rep.fpr);
    }
}

#[test]
fn fpr_falls_with_more_bits_per_key() {
    // sweep c = m/n by inserting fewer keys into the same filter
    let cfg = FilterConfig { log2_m_words: 14, ..Default::default() };
    let n_opt = space_optimal_n(cfg.m_bits(), cfg.k) as usize;
    let f_full = measure_fpr(&cfg, n_opt, 100_000, 5).unwrap();
    let f_half = measure_fpr(&cfg, n_opt / 2, 100_000, 5).unwrap();
    let f_quarter = measure_fpr(&cfg, n_opt / 4, 100_000, 5).unwrap();
    assert!(f_quarter <= f_half && f_half <= f_full, "{f_quarter} {f_half} {f_full}");
}

#[test]
fn cross_word_size_equivalence_s64_vs_s32() {
    // CBF and BBF derive bit positions from the *bit-level* geometry only
    // (log2_m_bits / log2_block_bits), never from the word size, so an
    // S = 64 and an S = 32 filter of matching total geometry (same m_bits,
    // B, k, scheme) hold bit-identical arrays: membership answers and FPR
    // measurements must match exactly, not just statistically.
    let cases = [
        FilterConfig { variant: Variant::Cbf, k: 16, log2_m_words: 13, word_bits: 64, ..Default::default() },
        FilterConfig { variant: Variant::Bbf, block_bits: 256, k: 16, log2_m_words: 13, word_bits: 64, ..Default::default() },
        FilterConfig {
            variant: Variant::Bbf,
            block_bits: 256,
            k: 16,
            scheme: Scheme::Iter,
            log2_m_words: 13,
            word_bits: 64,
            ..Default::default()
        },
    ];
    for cfg64 in cases {
        // same m_bits: one extra log2 word for half-width words
        let cfg32 = FilterConfig { word_bits: 32, log2_m_words: cfg64.log2_m_words + 1, ..cfg64 };
        assert_eq!(cfg64.m_bits(), cfg32.m_bits());
        let f_w64 = AnyBloom::new(cfg64).unwrap();
        let f_w32 = AnyBloom::new(cfg32).unwrap();
        let (ins, qry) = disjoint_key_sets(10_000, 10_000, 17);
        f_w64.bulk_add(&ins, 0);
        f_w32.bulk_add(&ins, 0);

        // identical membership answers, false positives included
        assert_eq!(f_w64.bulk_contains(&ins, 0), f_w32.bulk_contains(&ins, 0), "{}", cfg64.name());
        assert_eq!(f_w64.bulk_contains(&qry, 0), f_w32.bulk_contains(&qry, 0), "{}", cfg64.name());

        // the underlying bit arrays are identical: u64 word j is the pair
        // of u32 words (2j, 2j+1) in little-bit order
        let w64 = f_w64.snapshot();
        let w32 = f_w32.snapshot();
        assert_eq!(w32.len(), 2 * w64.len());
        for (j, &w) in w64.iter().enumerate() {
            let (lo, hi) = (w32[2 * j], w32[2 * j + 1]);
            assert_eq!(w, lo | (hi << 32), "{}: word {j}", cfg64.name());
        }

        // identical FPR measurement through analytics::fpr (same seed ->
        // same key sets -> bit-identical decisions -> the exact same rate);
        // overfill past the space-optimal load so the rate is reliably
        // nonzero and the equality is meaningful
        let fpr64 = measure_fpr(&cfg64, 60_000, 50_000, 29).unwrap();
        let fpr32 = measure_fpr(&cfg32, 60_000, 50_000, 29).unwrap();
        assert_eq!(fpr64, fpr32, "{}", cfg64.name());
        assert!(fpr64 > 0.0, "{}: want a nonzero rate so the equality is meaningful", cfg64.name());
    }
}

#[test]
fn merge_distributes_over_partitioned_builds() {
    // building two shards and merging == building one filter with all keys
    let cfg = FilterConfig { log2_m_words: 13, ..Default::default() };
    let keys = unique_keys(30_000, 9);
    let (a, b) = keys.split_at(15_000);
    let fa = Bloom::<u64>::new(cfg).unwrap();
    let fb = Bloom::<u64>::new(cfg).unwrap();
    fa.bulk_add(a, 0);
    fb.bulk_add(b, 0);
    fa.merge(&fb).unwrap();
    let full = Bloom::<u64>::new(cfg).unwrap();
    full.bulk_add(&keys, 0);
    assert_eq!(fa.snapshot(), full.snapshot());
}

#[test]
fn snapshot_transfers_between_engines() {
    // native -> words -> fresh filter (the PJRT state hand-off path)
    let cfg = FilterConfig { log2_m_words: 13, ..Default::default() };
    let keys = unique_keys(10_000, 11);
    let src = Bloom::<u64>::new(cfg).unwrap();
    src.bulk_add(&keys, 0);
    let dst = Bloom::<u64>::new(cfg).unwrap();
    dst.load_words(&src.snapshot()).unwrap();
    assert!(dst.bulk_contains(&keys, 0).iter().all(|&h| h));
}

#[test]
fn concurrent_insert_and_query_is_safe() {
    // lock-free adds while queries run: queries on inserted prefixes must
    // always hit (monotone filter growth can only add bits)
    let cfg = FilterConfig { log2_m_words: 14, ..Default::default() };
    let filter = std::sync::Arc::new(Bloom::<u64>::new(cfg).unwrap());
    let keys = unique_keys(64_000, 13);
    let phase1 = keys[..32_000].to_vec();
    filter.bulk_add(&phase1, 0);
    std::thread::scope(|scope| {
        let f2 = std::sync::Arc::clone(&filter);
        let rest = keys[32_000..].to_vec();
        scope.spawn(move || f2.bulk_add(&rest, 2));
        // concurrent queries of already-inserted keys
        for chunk in phase1.chunks(8_000) {
            assert!(filter.bulk_contains(chunk, 1).iter().all(|&h| h));
        }
    });
    assert!(filter.bulk_contains(&keys, 0).iter().all(|&h| h));
}
