//! PJRT round-trip integration: artifacts -> engine -> results must match
//! the native Rust filter library bit-for-bit.
//!
//! Requires `make artifacts` (skips with a note otherwise).

use gbf::filter::params::FilterConfig;
use gbf::filter::Bloom;
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};

fn engine() -> Option<(EngineActor, Manifest)> {
    let dir = default_artifact_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime integration: {e:#} (run `make artifacts`)");
            return None;
        }
    };
    let actor = EngineActor::spawn_with_manifest(manifest.clone()).expect("engine startup");
    Some((actor, manifest))
}

#[test]
fn pjrt_matches_native_for_every_artifact_config() {
    let Some((actor, manifest)) = engine() else { return };
    let client = actor.client();
    for cfg in manifest.configs() {
        let batches = manifest.batch_sizes(&cfg, "contains", "pallas");
        let batch = *batches.first().expect("at least one batch");
        let add_name = &manifest.find(&cfg, "add", batch, "pallas").unwrap().name;
        let contains_name = &manifest.find(&cfg, "contains", batch, "pallas").unwrap().name;

        // native oracle
        let native = Bloom::<u64>::new(cfg).unwrap();
        let keys = unique_keys(batch, 42);
        native.bulk_add(&keys, 1);

        // pjrt path
        let state = client.create_state(cfg).unwrap();
        client.add(add_name, state, keys.clone(), keys.len()).unwrap();

        // filter words must be bit-identical
        let pjrt_words = client.snapshot(state).unwrap();
        assert_eq!(pjrt_words, native.snapshot(), "filter words differ for {}", cfg.name());

        // lookups: hits for all inserted, mostly-miss for absent
        let hits = client.contains(contains_name, state, keys.clone()).unwrap();
        assert!(hits.iter().all(|&h| h == 1), "false negative via pjrt for {}", cfg.name());

        let absent = unique_keys(batch, 4242);
        let pjrt_hits = client.contains(contains_name, state, absent.clone()).unwrap();
        let native_hits = native.bulk_contains(&absent, 1);
        for (i, (&p, n)) in pjrt_hits.iter().zip(native_hits).enumerate() {
            assert_eq!(p != 0, n, "mismatch at {} for {}", i, cfg.name());
        }
        println!("config {} OK (batch {batch})", cfg.name());
    }
}

#[test]
fn pjrt_n_valid_masks_padding() {
    let Some((actor, manifest)) = engine() else { return };
    let client = actor.client();
    let cfg = FilterConfig::default();
    let batch = 256usize;
    let add_name = &manifest.find(&cfg, "add", batch, "pallas").unwrap().name;

    let keys = unique_keys(batch, 7);
    let n_valid = 100;
    let state = client.create_state(cfg).unwrap();
    client.add(add_name, state, keys.clone(), n_valid).unwrap();

    let native = Bloom::<u64>::new(cfg).unwrap();
    native.bulk_add(&keys[..n_valid], 1);
    assert_eq!(client.snapshot(state).unwrap(), native.snapshot());
}

#[test]
fn pjrt_jnp_ablation_matches_pallas() {
    let Some((actor, manifest)) = engine() else { return };
    let client = actor.client();
    let cfg = FilterConfig::default();
    let batch = 4096usize;
    let Some(jnp_add) = manifest.find(&cfg, "add", batch, "jnp") else {
        eprintln!("skipping: no jnp ablation artifacts");
        return;
    };
    let jnp_contains = manifest.find(&cfg, "contains", batch, "jnp").unwrap();
    let pallas_add = manifest.find(&cfg, "add", batch, "pallas").unwrap();
    let pallas_contains = manifest.find(&cfg, "contains", batch, "pallas").unwrap();

    let keys = unique_keys(batch, 9);
    let zero = vec![0u64; cfg.m_words() as usize];
    let w_jnp = client.add_words(&jnp_add.name, zero.clone(), keys.clone(), batch).unwrap();
    let w_pallas = client.add_words(&pallas_add.name, zero, keys.clone(), batch).unwrap();
    assert_eq!(w_jnp, w_pallas, "L2 jnp and L1 pallas add disagree");

    let probe = unique_keys(batch, 10);
    let h_jnp = client.contains_words(&jnp_contains.name, w_jnp.clone(), probe.clone()).unwrap();
    let h_pallas = client.contains_words(&pallas_contains.name, w_jnp, probe).unwrap();
    assert_eq!(h_jnp, h_pallas);
}

#[test]
fn pjrt_fpr_sane_at_scale() {
    let Some((actor, manifest)) = engine() else { return };
    let client = actor.client();
    let cfg = FilterConfig::default();
    let batch = 4096usize;
    let add_name = &manifest.find(&cfg, "add", batch, "pallas").unwrap().name;
    let contains_name = &manifest.find(&cfg, "contains", batch, "pallas").unwrap().name;

    // fill to the space-optimal load, then query absent keys
    let n = gbf::filter::params::space_optimal_n(cfg.m_bits(), cfg.k) as usize;
    let (ins, qry) = disjoint_key_sets(n, 4 * batch, 33);
    let state = client.create_state(cfg).unwrap();
    for chunk in ins.chunks(batch) {
        let mut padded = chunk.to_vec();
        padded.resize(batch, 0);
        client.add(add_name, state, padded, chunk.len()).unwrap();
    }
    let mut fp = 0usize;
    for chunk in qry.chunks(batch) {
        let hits = client.contains(contains_name, state, chunk.to_vec()).unwrap();
        fp += hits.iter().filter(|&&h| h != 0).count();
    }
    let fpr = fp as f64 / qry.len() as f64;
    let theory = gbf::filter::params::fpr_blocked(cfg.m_bits(), n as u64, cfg.k, cfg.block_bits);
    assert!(fpr < theory * 4.0 + 5e-3, "fpr {fpr} vs blocked theory {theory}");
}
