//! Shared integration-test helpers: the transport-agnostic acceptance
//! driver and its spec builders. `drive_api` is written purely against
//! `dyn FilterApi`, so the SAME body exercises the in-process
//! `FilterService`, a loopback `RemoteFilterService`, and the cluster
//! front end — identical answers, identical typed errors.
#![allow(dead_code)]

use std::time::Duration;

use gbf::coordinator::{BatchPolicy, FilterApi, FilterDataPlane, FilterSpec, GbfError};
use gbf::filter::params::FilterConfig;
use gbf::workload::keygen::unique_keys;

pub fn cfg(log2_m_words: u32) -> FilterConfig {
    FilterConfig { log2_m_words, ..Default::default() }
}

pub fn spec(log2_m_words: u32, shards: usize, max_batch: usize, wait_us: u64) -> FilterSpec {
    FilterSpec {
        config: cfg(log2_m_words),
        shards,
        policy: BatchPolicy { max_batch, max_wait: Duration::from_micros(wait_us) },
        ..FilterSpec::default()
    }
}

/// The acceptance driver: written purely against `dyn FilterApi`, so it
/// cannot tell whether the catalog is in-process or across a socket.
/// Returns the query answers and a stats snapshot for cross-transport
/// comparison.
pub fn drive_api(api: &dyn FilterApi) -> (Vec<bool>, gbf::coordinator::NamespaceStats) {
    // create (full spec), duplicate create -> typed FilterExists
    let h: Box<dyn FilterDataPlane> = api.create_filter_spec("eq", spec(14, 4, 1024, 150)).unwrap();
    match api.create_filter_spec("eq", FilterSpec::new(cfg(12), 1)) {
        Err(GbfError::FilterExists(n)) => assert_eq!(n, "eq"),
        Err(other) => panic!("expected FilterExists, got {other:?}"),
        Ok(_) => panic!("duplicate create must fail"),
    }

    // bulk + single data plane, pipelined tickets before any wait
    let keys = unique_keys(10_000, 0xE0);
    h.add_bulk(&keys).wait().unwrap();
    h.add(42).wait().unwrap();
    let mut probe = keys.clone();
    probe.extend(unique_keys(5_000, 0xE1));
    let t_bulk = h.query_bulk(&probe);
    let t_single = h.query(42);
    let hits = t_bulk.wait().unwrap();
    assert!(t_single.wait().unwrap());
    assert!(hits[..10_000].iter().all(|&x| x), "no false negatives via {}", h.name());

    // the bit-packed bulk path must answer identically on both
    // transports (in-process: straight off the sink; wire: the frame's
    // answer bytes handed through without a repack)
    let bits = h.query_bulk_bits(&probe).wait().unwrap();
    assert_eq!(bits.len(), probe.len());
    assert_eq!(bits.to_bools(), hits, "query_bulk_bits agrees with query_bulk via {}", h.name());

    // backpressure: a bounded namespace refuses oversized bulks with the
    // typed Overloaded error — deterministically, on both transports
    let bounded: Box<dyn FilterDataPlane> = api
        .create_filter_spec("eq-bounded", FilterSpec { max_queue_depth: Some(4), ..FilterSpec::new(cfg(12), 1) })
        .unwrap();
    match bounded.add_bulk(&unique_keys(64, 0xE2)).wait() {
        Err(GbfError::Overloaded { name, depth }) => {
            assert_eq!(name, "eq-bounded");
            assert!(depth > 4, "would-be depth reported: {depth}");
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    bounded.add_bulk(&[7, 8]).wait().unwrap(); // within the bound

    // admin plane: list, stats (incl. per-shard counters), typed misses
    assert_eq!(api.list_filters().unwrap(), vec!["eq".to_string(), "eq-bounded".to_string()]);
    let stats = api.stats("eq").unwrap();
    assert_eq!(stats.num_shards, 4);
    assert_eq!(stats.shards.len(), 4, "per-shard counters travel with stats");
    assert_eq!(stats.metrics.adds, 10_001);
    match api.stats("nope") {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "nope"),
        other => panic!("expected NoSuchFilter, got {other:?}"),
    }
    match api.handle("nope") {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "nope"),
        Err(other) => panic!("expected NoSuchFilter, got {other:?}"),
        Ok(_) => panic!("handle to a missing namespace must fail"),
    }

    // a fresh handle reaches the same state; drop, then typed miss
    let h2 = api.handle("eq").unwrap();
    assert!(h2.query(42).wait().unwrap());
    api.drop_filter("eq-bounded").unwrap();
    match api.drop_filter("eq-bounded") {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "eq-bounded"),
        other => panic!("expected NoSuchFilter, got {other:?}"),
    }

    // drop-then-recreate: handles pin the namespace INSTANCE, not the
    // name — on both transports a stale handle answers NoSuchFilter
    // instead of silently reaching the reborn namespace
    api.drop_filter("eq").unwrap();
    let reborn: Box<dyn FilterDataPlane> = api.create_filter_spec("eq", spec(14, 4, 1024, 150)).unwrap();
    match h2.query(42).wait() {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "eq"),
        other => panic!("stale handle must fail typed, got {other:?}"),
    }
    assert!(!reborn.query(42).wait().unwrap(), "reborn namespace starts empty");
    api.drop_filter("eq").unwrap();

    // snapshot/restore: the SAME body persists a namespace, drops it,
    // and warm-starts it — answers, counters, and stale-handle
    // semantics must be identical on both transports (paths resolve
    // server-side; loopback makes that this machine either way)
    let snap_dir = scratch_dir("drive-api-snap");
    let durable: Box<dyn FilterDataPlane> = api.create_filter_spec("eq-durable", spec(13, 2, 1024, 150)).unwrap();
    let snap_keys = unique_keys(3_000, 0xE3);
    durable.add_bulk(&snap_keys).wait().unwrap();
    let mut snap_probe = snap_keys.clone();
    snap_probe.extend(unique_keys(2_000, 0xE4));
    let pre_restore = durable.query_bulk(&snap_probe).wait().unwrap();
    api.snapshot("eq-durable", &snap_dir).unwrap();
    // snapshot of a missing namespace is a typed miss
    match api.snapshot("nope", &snap_dir) {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "nope"),
        other => panic!("expected NoSuchFilter, got {other:?}"),
    }
    // restore onto a live name is refused like a duplicate create
    match api.restore("eq-durable", &snap_dir) {
        Err(GbfError::FilterExists(n)) => assert_eq!(n, "eq-durable"),
        Err(other) => panic!("expected FilterExists, got {other:?}"),
        Ok(_) => panic!("restore onto a live name must fail"),
    }
    api.drop_filter("eq-durable").unwrap();
    let warm = api.restore("eq-durable", &snap_dir).unwrap();
    // the pre-restore handle is stale on both transports
    match durable.query(snap_keys[0]).wait() {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "eq-durable"),
        other => panic!("pre-restore stale handle must fail typed, got {other:?}"),
    }
    let post_restore = warm.query_bulk(&snap_probe).wait().unwrap();
    assert_eq!(pre_restore, post_restore, "restored namespace answers identically via {}", warm.name());
    assert_eq!(api.stats("eq-durable").unwrap().metrics.adds, 3_000, "restored key counters");
    // restoring garbage is a typed refusal on both transports
    match api.restore("eq-fresh", &snap_dir.join("missing")) {
        Err(GbfError::SnapshotCorrupt(_)) => {}
        Err(other) => panic!("expected SnapshotCorrupt, got {other:?}"),
        Ok(_) => panic!("restore from a missing snapshot must fail"),
    }
    api.drop_filter("eq-durable").unwrap();
    std::fs::remove_dir_all(&snap_dir).ok();

    assert!(api.list_filters().unwrap().is_empty());
    (hits, stats)
}

/// Unique scratch directory (drive_api runs once per transport; the
/// snapshot paths must not collide).
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "gbf-{tag}-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ))
}
