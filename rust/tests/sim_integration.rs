//! GPU-model integration: the analytic transaction model vs the
//! trace-driven coalescer, plus end-to-end experiment harness checks.

use gbf::experiments;
use gbf::filter::params::{FilterConfig, Variant};
use gbf::gpu_sim::coalescer::{add_trace, Coalescer};
use gbf::gpu_sim::{model, Features, Op, Residency, B200};
use gbf::workload::keygen::unique_keys;

fn sbf(block_bits: u32) -> FilterConfig {
    let variant = if block_bits == 64 { Variant::Rbbf } else { Variant::Sbf };
    FilterConfig { variant, block_bits, k: 16, log2_m_words: 22, ..Default::default() }
}

#[test]
fn coalescer_confirms_horizontal_add_ordering() {
    // The analytic model says add transactions shrink monotonically with Θ;
    // the trace-driven coalescer must agree on the ordering.
    let keys = unique_keys(32 * 64, 1);
    for block_bits in [256u32, 512, 1024] {
        let cfg = sbf(block_bits);
        let mut last_trace = f64::MAX;
        let mut last_model = f64::MAX;
        for theta in model::theta_grid(&cfg) {
            let stats = Coalescer::default().run(&add_trace(&cfg, theta, 1, &keys));
            let per_op = stats.transactions as f64 / keys.len() as f64;
            let p = model::predict(&cfg, Op::Add, theta, 1, Residency::Dram, &B200, Features::default());
            assert!(per_op <= last_trace + 0.05, "B={block_bits} Θ={theta}: trace {per_op} vs {last_trace}");
            assert!(
                p.sector_transactions <= last_model + 0.05,
                "B={block_bits} Θ={theta}: model"
            );
            last_trace = per_op;
            last_model = p.sector_transactions;
        }
        // at Θ = s both agree the block collapses to ~1-4 transactions
        assert!(last_trace <= (block_bits / 256).max(1) as f64 + 0.3, "B={block_bits}: {last_trace}");
    }
}

#[test]
fn coalescer_traffic_volume_is_layout_invariant() {
    // merging changes transactions, never sectors touched
    let keys = unique_keys(32 * 32, 2);
    let cfg = sbf(512);
    let sectors: Vec<u64> = model::theta_grid(&cfg)
        .into_iter()
        .map(|theta| Coalescer::default().run(&add_trace(&cfg, theta, 1, &keys)).sectors)
        .collect();
    assert!(sectors.windows(2).all(|w| w[0] == w[1]), "{sectors:?}");
}

#[test]
fn experiment_harness_runs_every_figure() {
    for exp in ["table1", "table2", "fig5", "fig6", "fig7", "fig8", "fig9", "calibration"] {
        let text = experiments::run(exp, None).unwrap_or_else(|e| panic!("{exp}: {e:#}"));
        assert!(text.len() > 100, "{exp} produced no output");
    }
}

#[test]
fn headline_speedup_claims_hold_in_model() {
    // §5.3: "for B = 256, the speedup increases to 11.35x (15.4x)" vs
    // WarpCore for add (contains) in the cache-resident regime. The model
    // must land in the right decade (see EXPERIMENTS.md for exact values).
    let ours = sbf(256);
    let mut wc = FilterConfig {
        variant: Variant::Bbf,
        block_bits: 256,
        k: 16,
        scheme: gbf::filter::params::Scheme::Iter,
        log2_m_words: 22,
        ..Default::default()
    };
    wc.theta = wc.s();
    let wc_feats = Features { mult_hash: false, adaptive_coop: false, horizontal_vec: true };
    for (op, claimed) in [(Op::Add, 11.35), (Op::Contains, 15.4)] {
        let us = model::best_layout(&ours, op, Residency::L2, &B200, Features::default()).2;
        let them = model::predict(&wc, op, wc.s(), 1, Residency::L2, &B200, wc_feats);
        let speedup = us.gelems_per_sec / them.gelems_per_sec;
        assert!(
            speedup > claimed / 2.0 && speedup < claimed * 2.0,
            "{op:?}: modeled speedup {speedup:.1} vs paper {claimed}"
        );
    }
}

#[test]
fn cbf_tradeoff_claims_hold() {
    // §5.2: SBF B=256 is 15.3x (5.4x) faster than CBF for add (contains)
    // at DRAM, while CBF has ~2 orders of magnitude lower FPR.
    let ours = sbf(256);
    let cbf = FilterConfig { variant: Variant::Cbf, k: 16, log2_m_words: 27, ..Default::default() };
    let ours_dram = FilterConfig { log2_m_words: 27, ..ours };
    for (op, claimed) in [(Op::Add, 15.3), (Op::Contains, 5.4)] {
        let us = model::best_layout(&ours_dram, op, Residency::Dram, &B200, Features::default()).2;
        let them = model::predict(&cbf, op, 1, 1, Residency::Dram, &B200, Features::default());
        let speedup = us.gelems_per_sec / them.gelems_per_sec;
        assert!(
            speedup > claimed / 2.0 && speedup < claimed * 2.0,
            "{op:?}: modeled speedup {speedup:.1} vs paper {claimed}"
        );
    }
}

#[test]
fn stall_counters_expose_paper_profiling_story() {
    // §5.2: B > 256 lookups stall on mmio_throttle at Θ=1 (register
    // pressure kills occupancy), adds on drain
    let cfg = sbf(1024);
    let c = model::predict(&cfg, Op::Contains, 1, 16, Residency::Dram, &B200, Features::default());
    assert_eq!(c.stall, gbf::gpu_sim::StallCause::MmioThrottle);
    assert!(c.occupancy < 0.5);
    let a = model::predict(&cfg, Op::Add, 1, 1, Residency::Dram, &B200, Features::default());
    assert_eq!(a.stall, gbf::gpu_sim::StallCause::Drain);
    // and the healthy configurations do not stall
    let ok = model::predict(&sbf(256), Op::Contains, 1, 4, Residency::Dram, &B200, Features::default());
    assert_eq!(ok.stall, gbf::gpu_sim::StallCause::MemoryThroughput);
}
