//! Wire-client reconnect: a `RemoteFilterService` outlives its server.
//! While the server is away every call fails *fast* with a typed
//! connection error (dial refusals and the reconnect-backoff cooldown
//! both surface as `GbfError::Backend`, never a hang); once a server
//! appears at the address, `ping_now` clears the cooldown and the same
//! client object carries a full lifecycle without being rebuilt.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gbf::coordinator::{FilterService, GbfError, RemoteFilterService, WireServer};
use gbf::workload::keygen::unique_keys;

mod common;
use common::cfg;

#[test]
fn lazy_client_rides_out_a_late_server_start() {
    // reserve an address nobody is listening on yet
    let reserved = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = reserved.local_addr().unwrap().to_string();
    drop(reserved);

    let client = RemoteFilterService::connect_lazy(addr.as_str()).unwrap();

    // server away: every call is a typed, bounded-time failure — the
    // first burns real dial attempts, later ones may hit the backoff
    // cooldown, and all of them are GbfError::Backend
    let started = Instant::now();
    for _ in 0..4 {
        match client.list_filters() {
            Err(GbfError::Backend(msg)) => {
                assert!(msg.starts_with("wire client"), "typed connection error, got {msg:?}");
            }
            other => panic!("expected Backend while the server is away, got {other:?}"),
        }
    }
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "failures while down must be bounded, took {:?}",
        started.elapsed()
    );

    // the server arrives at the reserved address; ping_now clears the
    // reconnect cooldown so recovery is deterministic, not a sleep
    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), addr.as_str()).unwrap();
    client.ping_now().unwrap();

    // the SAME client object now carries a full lifecycle
    let h = client.create_filter("late", cfg(13), 2).unwrap();
    let keys = unique_keys(2_000, 0x77);
    h.add_bulk(&keys).wait().unwrap();
    assert!(h.query_bulk(&keys).wait().unwrap().iter().all(|&hit| hit));
    assert_eq!(client.stats("late").unwrap().metrics.adds, 2_000);
    client.drop_filter("late").unwrap();

    // and when the server goes away again, errors are typed again
    drop(server);
    let mut saw_error = false;
    for _ in 0..50 {
        match client.list_filters() {
            Err(GbfError::Backend(_)) => {
                saw_error = true;
                break;
            }
            Ok(_) => std::thread::sleep(Duration::from_millis(10)),
            Err(other) => panic!("expected Backend after shutdown, got {other:?}"),
        }
    }
    assert!(saw_error, "calls after shutdown fail with GbfError::Backend");
}

#[test]
fn idempotent_retries_are_invisible_to_the_caller() {
    // a live server: ping (the idempotent probe) and the admin plane
    // agree; ping is also safe to hammer
    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    for _ in 0..10 {
        client.ping().unwrap();
    }
    client.create_filter("idem", cfg(12), 1).unwrap();
    assert_eq!(client.list_filters().unwrap(), vec!["idem".to_string()]);

    // ping against a dead server is a typed failure, not a hang
    drop(server);
    let started = Instant::now();
    match client.ping_now() {
        Err(GbfError::Backend(_)) => {}
        other => panic!("expected Backend from ping on a dead server, got {other:?}"),
    }
    assert!(started.elapsed() < Duration::from_secs(30));
}
