//! Snapshot-manifest fuzzing (ISSUE 6 tentpole leg 3): the JSON manifest
//! parser (`SnapshotManifest::from_json_str`) against the committed
//! regression corpus (`rust/corpus/manifest/*.json`) and a deterministic
//! seeded mutation sweep over valid documents.
//!
//! Property: arbitrary bytes produce `Ok(manifest)` or a *typed*
//! [`GbfError`] — never a panic, never a stack overflow (the corpus pins
//! the deep-nesting finding), never an integer-truncation acceptance (the
//! version-lie entry). Accepted documents must round-trip through
//! `to_json` as a fixed point.

use std::panic::{catch_unwind, AssertUnwindSafe};

use gbf::coordinator::persist::{SnapshotManifest, SNAPSHOT_VERSION};
use gbf::coordinator::GbfError;
use gbf::infra::fuzz::{corpus_dir, load_corpus, Mutator};

fn manifest_corpus() -> Vec<(String, Vec<u8>)> {
    load_corpus(&corpus_dir("manifest"))
        .expect("manifest corpus present")
        .into_iter()
        .map(|(path, bytes)| {
            let name = path.file_name().expect("file name").to_string_lossy().into_owned();
            (name, bytes)
        })
        .collect()
}

fn entry(corpus: &[(String, Vec<u8>)], name: &str) -> String {
    let bytes = &corpus
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("corpus entry {name} missing"))
        .1;
    String::from_utf8_lossy(bytes).into_owned()
}

#[test]
fn corpus_replay_never_panics() {
    let corpus = manifest_corpus();
    assert!(corpus.len() >= 7, "manifest corpus unexpectedly small: {}", corpus.len());
    for (name, bytes) in &corpus {
        let text = String::from_utf8_lossy(bytes).into_owned();
        let outcome = catch_unwind(AssertUnwindSafe(|| SnapshotManifest::from_json_str(&text).map(|_| ())));
        assert!(outcome.is_ok(), "corpus entry {name} panicked the manifest parser");
    }
}

#[test]
fn valid_corpus_entry_round_trips() {
    let corpus = manifest_corpus();
    let manifest = SnapshotManifest::from_json_str(&entry(&corpus, "valid.json")).expect("valid.json parses");
    assert_eq!(manifest.name, "ns");
    assert_eq!(manifest.format_version, SNAPSHOT_VERSION);
    assert_eq!(manifest.shard_files.len(), 1);
    assert_eq!(manifest.shard_files[0].checksum, 0xDEAD_BEEF_0000_0000);
    let reparsed = SnapshotManifest::from_json_str(&manifest.to_json()).expect("round trip parses");
    assert_eq!(manifest, reparsed, "to_json must be a parse fixed point");
}

/// The policy block is optional-but-validated: a manifest carrying one
/// round-trips it exactly, and a doctored zero `max_batch` (a policy that
/// could never drain the queue) is a typed geometry refusal — the same
/// standard the wire create path holds hostile specs to.
#[test]
fn policy_corpus_entries() {
    let corpus = manifest_corpus();
    let m = SnapshotManifest::from_json_str(&entry(&corpus, "policy.json")).expect("policy.json parses");
    assert_eq!(m.max_batch, Some(512));
    assert_eq!(m.max_queue_depth, Some(4096));
    let reparsed = SnapshotManifest::from_json_str(&m.to_json()).expect("round trip parses");
    assert_eq!(m, reparsed, "policy block survives the to_json fixed point");
    match SnapshotManifest::from_json_str(&entry(&corpus, "policy-zero-batch.json")) {
        Err(GbfError::SnapshotGeometry(msg)) => assert!(msg.contains("max_batch"), "{msg}"),
        other => panic!("zero max_batch must be SnapshotGeometry, got {other:?}"),
    }
}

/// Regression (fuzzer finding): a doctored `format_version` of 2^32 + 1
/// must not truncate into "version 1, supported" — the comparison happens
/// in u64 and the error saturates the reported value.
#[test]
fn version_lie_corpus_entry_does_not_truncate() {
    let corpus = manifest_corpus();
    match SnapshotManifest::from_json_str(&entry(&corpus, "version-lie.json")) {
        Err(GbfError::SnapshotVersion { found, supported }) => {
            assert_eq!(found, u32::MAX, "out-of-range version saturates, never truncates");
            assert_eq!(supported, SNAPSHOT_VERSION);
        }
        other => panic!("version lie must be SnapshotVersion, got {other:?}"),
    }
    match SnapshotManifest::from_json_str(&entry(&corpus, "version-future.json")) {
        Err(GbfError::SnapshotVersion { found: 2, .. }) => {}
        other => panic!("future version must be SnapshotVersion, got {other:?}"),
    }
}

/// Regression (fuzzer finding): deeply-nested input must come back as a
/// typed corruption error from the parser's depth bound — before the fix,
/// `[` * 2000 recursed the JSON parser toward a stack overflow.
#[test]
fn deep_nesting_corpus_entry_is_typed_error() {
    let corpus = manifest_corpus();
    match SnapshotManifest::from_json_str(&entry(&corpus, "deep-nesting.json")) {
        Err(GbfError::SnapshotCorrupt(msg)) => assert!(msg.contains("nesting"), "{msg}"),
        other => panic!("deep nesting must be SnapshotCorrupt, got {other:?}"),
    }
}

#[test]
fn hostile_corpus_entries_fail_typed() {
    let corpus = manifest_corpus();
    match SnapshotManifest::from_json_str(&entry(&corpus, "path-escape.json")) {
        Err(GbfError::SnapshotCorrupt(msg)) => assert!(msg.contains("escapes"), "{msg}"),
        other => panic!("path escape must be SnapshotCorrupt, got {other:?}"),
    }
    match SnapshotManifest::from_json_str(&entry(&corpus, "words-mismatch.json")) {
        Err(GbfError::SnapshotGeometry(_)) => {}
        other => panic!("word-count mismatch must be SnapshotGeometry, got {other:?}"),
    }
    match SnapshotManifest::from_json_str(&entry(&corpus, "checksum-not-hex.json")) {
        Err(GbfError::SnapshotCorrupt(_)) => {}
        other => panic!("non-hex checksum must be SnapshotCorrupt, got {other:?}"),
    }
    match SnapshotManifest::from_json_str(&entry(&corpus, "shards-zero.json")) {
        Err(GbfError::SnapshotGeometry(_)) => {}
        other => panic!("zero shards must be SnapshotGeometry, got {other:?}"),
    }
}

#[test]
fn mutation_sweep_manifests() {
    let seed = std::env::var("GBF_FUZZ_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x00C0_FFEEu64);
    let iters: u64 = std::env::var("GBF_FUZZ_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let corpus = manifest_corpus();
    let valid = entry(&corpus, "valid.json").into_bytes();
    // A second valid document (different geometry) gives splices structure.
    let other = {
        let mut m = SnapshotManifest::from_json_str(&entry(&corpus, "valid.json")).expect("valid");
        m.name = "other".into();
        m.adds = 99;
        m.to_json().into_bytes()
    };
    let mut m = Mutator::new(seed);
    for i in 0..iters {
        let mutant = m.mutate(&valid, &other);
        let text = String::from_utf8_lossy(&mutant).into_owned();
        let outcome = catch_unwind(AssertUnwindSafe(|| SnapshotManifest::from_json_str(&text)));
        let parsed = outcome
            .unwrap_or_else(|_| panic!("manifest parser panicked (seed {seed}, iter {i}): {text:?}"));
        if let Ok(manifest) = parsed {
            let reparsed = SnapshotManifest::from_json_str(&manifest.to_json())
                .unwrap_or_else(|e| panic!("accepted mutant failed round trip (seed {seed}, iter {i}): {e:?}"));
            assert_eq!(manifest, reparsed, "seed {seed}, iter {i}");
        }
    }
}
