//! Bulk ≡ scalar equivalence — the bulk kernels' correctness contract.
//!
//! For every variant × word size (S ∈ {32, 64}) × shard count
//! (1/2/4/8), property-checked via `infra/prop`:
//!
//! * `bulk_add` (the insert kernels) produces **byte-identical filter
//!   words** to the per-key scalar `add` loop;
//! * `bulk_contains_bits` / `bulk_contains` produce **identical answer
//!   bits** to the per-key scalar `contains` loop, hits, misses, and
//!   false positives alike.
//!
//! Plus the `AnswerBits` reply-path round-trips: a `Ticket<AnswerBits>`
//! resolves to the same answers as the `Vec<bool>` path, in-process and
//! across a loopback wire connection (where the frame's answer bytes are
//! handed through without a repack).

use std::sync::Arc;

use gbf::coordinator::{FilterService, RemoteFilterService, ShardedRegistry, WireServer};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::filter::AnswerBits;
use gbf::infra::prop::check;
use gbf::workload::keygen::unique_keys;

/// The five variants at both word sizes (geometries mirror the engine's
/// own unit-test grids).
fn cfgs_for_word(word_bits: u32) -> Vec<FilterConfig> {
    let m = 10u32;
    if word_bits == 64 {
        vec![
            FilterConfig { variant: Variant::Sbf, block_bits: 256, k: 16, log2_m_words: m, ..Default::default() },
            FilterConfig { variant: Variant::Bbf, block_bits: 256, k: 16, log2_m_words: m, ..Default::default() },
            FilterConfig { variant: Variant::Rbbf, block_bits: 64, k: 16, log2_m_words: m, ..Default::default() },
            FilterConfig {
                variant: Variant::Csbf,
                block_bits: 512,
                k: 16,
                z: 2,
                log2_m_words: m,
                ..Default::default()
            },
            FilterConfig { variant: Variant::Cbf, k: 16, log2_m_words: m, ..Default::default() },
        ]
    } else {
        vec![
            FilterConfig {
                variant: Variant::Sbf,
                block_bits: 128,
                word_bits: 32,
                k: 8,
                log2_m_words: m,
                ..Default::default()
            },
            FilterConfig {
                variant: Variant::Bbf,
                block_bits: 256,
                word_bits: 32,
                k: 16,
                log2_m_words: m,
                ..Default::default()
            },
            FilterConfig {
                variant: Variant::Rbbf,
                block_bits: 32,
                word_bits: 32,
                k: 16,
                log2_m_words: m,
                ..Default::default()
            },
            FilterConfig {
                variant: Variant::Csbf,
                block_bits: 512,
                word_bits: 32,
                k: 16,
                z: 2,
                log2_m_words: m,
                ..Default::default()
            },
            FilterConfig { variant: Variant::Cbf, word_bits: 32, k: 16, log2_m_words: m, ..Default::default() },
        ]
    }
}

#[test]
fn bulk_equals_scalar_for_every_variant_word_size_and_shard_count() {
    for word_bits in [64u32, 32] {
        for cfg in cfgs_for_word(word_bits) {
            for shards in [1usize, 2, 4, 8] {
                let label = format!("bulk-eq/{}/{}sh", cfg.name(), shards);
                check(&label, 2, |g| {
                    let scalar = ShardedRegistry::new(cfg, shards).unwrap();
                    let bulk = ShardedRegistry::new(cfg, shards).unwrap();
                    let keys = g.keys(1200);
                    for &k in &keys {
                        scalar.add(k);
                    }
                    bulk.bulk_add(&keys).unwrap();
                    assert_eq!(
                        scalar.snapshot_concat(),
                        bulk.snapshot_concat(),
                        "insert kernels must write byte-identical filter words"
                    );
                    let mut probe = keys.clone();
                    probe.extend(g.keys(1200)); // absent tail (incl. FPs)
                    let mut bits = AnswerBits::new();
                    bulk.bulk_contains_bits(&probe, &mut bits).unwrap();
                    let vec_path = bulk.bulk_contains(&probe).unwrap();
                    assert_eq!(bits.len(), probe.len());
                    for (i, &key) in probe.iter().enumerate() {
                        let want = scalar.contains(key);
                        assert_eq!(bits.get(i), want, "key {key:#x} (bit-packed path)");
                        assert_eq!(vec_path[i], want, "key {key:#x} (vec path)");
                    }
                    // inserted keys must hit through every path
                    assert!(bits.iter().take(keys.len()).all(|b| b), "no false negatives");
                });
            }
        }
    }
}

#[test]
fn answer_bits_flow_through_tickets_and_the_wire_without_repack() {
    let service = Arc::new(FilterService::new());
    let cfg = FilterConfig { log2_m_words: 12, ..Default::default() };
    service.create_filter("bits", cfg, 2).unwrap();
    let h = service.handle("bits").unwrap();
    let keys = unique_keys(3_000, 77);
    h.add_bulk(&keys).wait().unwrap();
    let mut probe = keys.clone();
    probe.extend(unique_keys(3_000, 78));

    // in-process: a Ticket<AnswerBits> resolves to the same answers as
    // the Vec<bool> convenience path
    let bits = h.query_bulk_bits(&probe).wait().unwrap();
    let bools = h.query_bulk(&probe).wait().unwrap();
    assert_eq!(bits.len(), probe.len());
    assert_eq!(bits.to_bools(), bools);
    assert!(bits.iter().take(keys.len()).all(|b| b), "no false negatives");

    // across the wire: the loopback remote's ticket resolves the SAME
    // AnswerBits — the frame's answer bytes handed through unrepacked
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    let rh = client.handle("bits").unwrap();
    let remote_bits = rh.query_bulk_bits(&probe).wait().unwrap();
    assert_eq!(remote_bits, bits, "identical bit-packed answers across transports");

    // empty bulks resolve ready on both transports
    assert!(h.query_bulk_bits(&[]).wait().unwrap().is_empty());
    assert!(rh.query_bulk_bits(&[]).wait().unwrap().is_empty());
}
