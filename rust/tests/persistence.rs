//! Persistence torture tests: the durable-namespace subsystem
//! (`coordinator::persist` + `snapshot`/`restore` on the admin plane)
//! under friendly and hostile conditions.
//!
//! * **Round trip, property-tested**: random geometry across all five
//!   filter variants × both word sizes × 1/2/4/8 shards, random fill —
//!   snapshot → restore must be the identity (byte-identical words,
//!   identical query answers down to the false positives).
//! * **Corruption matrix**: truncation, bit flips, version bumps, and
//!   geometry edits must each come back as the *right* typed
//!   [`GbfError`] — never a panic, never catalog residue, never a
//!   wedged service.
//! * **Crash safety**: a writer killed between shard files and the
//!   manifest publish leaves the destination fully old (or absent) —
//!   a restore never observes a torn state.
//! * **Restart acceptance**: a multi-namespace catalog snapshotted,
//!   "restarted" (fresh `FilterService`), and restored over BOTH
//!   transports with byte-identical state.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use gbf::coordinator::persist::{shard_file_name, SnapshotWriter, MANIFEST_FILE};
use gbf::coordinator::{
    BatchPolicy, FilterService, FilterSpec, GbfError, RemoteFilterService, ShardedRegistry, WireServer,
};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::infra::prop::{check, Gen};
use gbf::workload::keygen::unique_keys;

/// Fresh scratch directory per call (parallel tests must not collide).
fn scratch(tag: &str) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "gbf-persist-{tag}-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ))
}

// ---- property-based round trip across the whole config grid ----

/// Valid geometry shapes covering all five variants × both word sizes
/// (variant, word_bits, block_bits, k, z).
const SHAPES: [(Variant, u32, u32, u32, u32); 10] = [
    (Variant::Cbf, 64, 256, 8, 1),
    (Variant::Cbf, 32, 256, 8, 1),
    (Variant::Bbf, 64, 256, 8, 1),
    (Variant::Bbf, 32, 128, 8, 1),
    (Variant::Rbbf, 64, 64, 16, 1),
    (Variant::Rbbf, 32, 32, 8, 1),
    (Variant::Sbf, 64, 256, 16, 1),
    (Variant::Sbf, 32, 128, 8, 1),
    (Variant::Csbf, 64, 512, 16, 2),
    (Variant::Csbf, 32, 256, 8, 2),
];

#[test]
fn property_snapshot_restore_is_the_identity() {
    check("snapshot-restore-identity", 12, |g: &mut Gen| {
        let &(variant, word_bits, block_bits, k, z) = g.choose(&SHAPES);
        let config = FilterConfig {
            variant,
            word_bits,
            block_bits,
            k,
            z,
            log2_m_words: g.range(10, 13) as u32,
            ..Default::default()
        }
        .validate()
        .expect("shape table only holds valid configs");
        let shards = g.pow2(0, 3) as usize; // 1 / 2 / 4 / 8
        let keys = g.keys(g.range(300, 2_000) as usize);
        let misses = unique_keys(1_000, g.u64() | 1);

        let dir = scratch("prop");
        let original = FilterService::new();
        let h = original.create_filter("prop", config, shards).unwrap();
        h.add_bulk(&keys).wait().unwrap();
        original.snapshot("prop", &dir).unwrap();

        let restored = FilterService::new();
        let r = restored.restore("prop", &dir).unwrap();
        // byte-identical state, shard for shard
        assert_eq!(r.snapshot_words(), h.snapshot_words(), "{}/{shards} shards", config.name());
        assert_eq!(r.num_shards(), shards);
        // identical answers: every inserted key hits, and the miss probes
        // agree down to the false positives
        assert!(r.query_bulk(&keys).wait().unwrap().iter().all(|&x| x), "{}", config.name());
        assert_eq!(
            h.query_bulk(&misses).wait().unwrap(),
            r.query_bulk(&misses).wait().unwrap(),
            "identical false-positive pattern for {}",
            config.name()
        );
        // key counters survive
        assert_eq!(restored.stats("prop").unwrap().metrics.adds, keys.len() as u64);
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// The batching/backpressure policy is part of what a snapshot preserves:
/// a restart must rebuild the namespace with its real scheduling — and a
/// pre-policy manifest (no `policy` block) must keep restoring with
/// defaults rather than failing.
#[test]
fn policy_survives_the_restart_and_old_manifests_still_restore() {
    let dir = scratch("policy");
    let config = FilterConfig { log2_m_words: 12, ..Default::default() };
    let service = FilterService::new();
    let spec = FilterSpec {
        config,
        shards: 2,
        policy: BatchPolicy { max_batch: 256, ..Default::default() },
        max_queue_depth: Some(512),
    };
    let h = service.create_filter_spec("tuned", spec).unwrap();
    h.add_bulk(&unique_keys(400, 0xA5)).wait().unwrap();
    service.snapshot("tuned", &dir).unwrap();
    // the manifest records the policy (key-sorted compact JSON)...
    let text = std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
    assert!(text.contains("\"policy\":{\"max_batch\":256,\"max_queue_depth\":512}"), "{text}");
    // ...and a restart rebuilds the namespace with it
    let restarted = FilterService::new();
    let r = restarted.restore("tuned", &dir).unwrap();
    assert_eq!(restarted.stats("tuned").unwrap().max_queue_depth, Some(512));
    assert_eq!(r.snapshot_words(), h.snapshot_words());
    // a pre-policy manifest — the same document without the block —
    // restores with defaults instead of failing
    let old_dir = scratch("policy-old");
    copy_snapshot(&dir, &old_dir);
    edit_manifest(&old_dir, ",\"policy\":{\"max_batch\":256,\"max_queue_depth\":512}", "");
    let legacy = FilterService::new();
    legacy.restore("tuned", &old_dir).unwrap();
    assert_eq!(legacy.stats("tuned").unwrap().max_queue_depth, None, "policy-less manifest means defaults");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&old_dir).ok();
}

// ---- corruption matrix: every mutilation gets its typed refusal ----

/// A populated two-shard snapshot to mutilate (pristine per test case).
fn pristine_snapshot(dir: &Path) -> Vec<u64> {
    let config = FilterConfig { log2_m_words: 12, ..Default::default() };
    let service = FilterService::new();
    let h = service.create_filter("victim", config, 2).unwrap();
    h.add_bulk(&unique_keys(3_000, 0xC0)).wait().unwrap();
    service.snapshot("victim", dir).unwrap();
    h.snapshot_words()
}

fn copy_snapshot(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

fn edit_manifest(dir: &Path, from: &str, to: &str) {
    let path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains(from), "manifest must contain {from:?} to corrupt it: {text}");
    std::fs::write(&path, text.replace(from, to)).unwrap();
}

#[test]
fn corruption_matrix_returns_the_right_typed_error() {
    let pristine = scratch("matrix-pristine");
    let words = pristine_snapshot(&pristine);

    // (tag, mutilation, check on the resulting error)
    type Check = fn(&GbfError) -> bool;
    let cases: Vec<(&str, Box<dyn Fn(&Path)>, Check)> = vec![
        (
            "truncated-shard",
            Box::new(|d: &Path| {
                let p = d.join(shard_file_name(0));
                let mut bytes = std::fs::read(&p).unwrap();
                bytes.truncate(bytes.len() / 2);
                std::fs::write(&p, bytes).unwrap();
            }),
            |e| matches!(e, GbfError::SnapshotCorrupt(_)),
        ),
        (
            "bit-flipped-shard",
            Box::new(|d: &Path| {
                let p = d.join(shard_file_name(1));
                let mut bytes = std::fs::read(&p).unwrap();
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x10;
                std::fs::write(&p, bytes).unwrap();
            }),
            |e| matches!(e, GbfError::SnapshotChecksum { shard: 1, .. }),
        ),
        (
            "version-bumped-manifest",
            Box::new(|d: &Path| edit_manifest(d, "\"format_version\":1", "\"format_version\":99")),
            |e| matches!(e, GbfError::SnapshotVersion { found: 99, supported: 1 }),
        ),
        (
            "geometry-mutated-manifest",
            Box::new(|d: &Path| edit_manifest(d, "\"log2_m_words\":12", "\"log2_m_words\":11")),
            |e| matches!(e, GbfError::SnapshotGeometry(_)),
        ),
        (
            "missing-shard-file",
            Box::new(|d: &Path| std::fs::remove_file(d.join(shard_file_name(1))).unwrap()),
            |e| matches!(e, GbfError::SnapshotCorrupt(_)),
        ),
        (
            "garbage-manifest",
            Box::new(|d: &Path| std::fs::write(d.join(MANIFEST_FILE), b"}{ not json").unwrap()),
            |e| matches!(e, GbfError::SnapshotCorrupt(_)),
        ),
    ];

    for (tag, mutilate, is_right) in cases {
        let dir = scratch(tag);
        copy_snapshot(&pristine, &dir);
        mutilate(&dir);
        let service = FilterService::new();
        let err = service.restore("victim", &dir).expect_err(tag);
        assert!(is_right(&err), "{tag}: wrong error variant {err:?}");
        // typed refusal, no residue: the catalog is empty and fully usable
        assert!(service.list_filters().is_empty(), "{tag}: failed restore left residue");
        let h = service.create_filter("alive", FilterConfig { log2_m_words: 10, ..Default::default() }, 1).unwrap();
        h.add(7).wait().unwrap();
        assert!(h.query(7).wait().unwrap(), "{tag}: service wedged after refusal");
        // and the pristine snapshot still restores fine on the same service
        let r = service.restore("victim", &pristine).unwrap();
        assert_eq!(r.snapshot_words(), words, "{tag}: pristine copy unaffected");
        std::fs::remove_dir_all(&dir).ok();
    }
    std::fs::remove_dir_all(&pristine).ok();
}

// ---- crash safety: fully old or fully new, never torn ----

#[test]
fn crash_mid_snapshot_leaves_old_or_nothing() {
    let cfg = FilterConfig { log2_m_words: 11, ..Default::default() };
    let reg = ShardedRegistry::new(cfg, 2).unwrap();
    reg.bulk_add(&unique_keys(2_000, 0xD0)).unwrap();
    let dir = scratch("crash");

    // crash before the FIRST snapshot ever commits: destination absent
    let mut w = SnapshotWriter::begin(&dir, "crash", &cfg, 2).unwrap();
    w.write_shard(0, &reg.snapshot_shard(0)).unwrap();
    drop(w); // the "kill" — between shard files and the manifest publish
    assert!(!dir.exists(), "a never-committed snapshot must not materialize");
    assert!(matches!(FilterService::new().restore("crash", &dir), Err(GbfError::SnapshotCorrupt(_))));

    // publish v1 for real
    let mut w = SnapshotWriter::begin(&dir, "crash", &cfg, 2).unwrap();
    for i in 0..2 {
        w.write_shard(i, &reg.snapshot_shard(i)).unwrap();
    }
    w.commit(2_000, 0).unwrap();
    let v1 = reg.snapshot_concat();

    // the state moves on; an overwriting snapshot crashes mid-write
    reg.bulk_add(&unique_keys(2_000, 0xD1)).unwrap();
    let mut w = SnapshotWriter::begin(&dir, "crash", &cfg, 2).unwrap();
    w.write_shard(0, &reg.snapshot_shard(0)).unwrap();
    drop(w); // kill between shard files and manifest
    let svc = FilterService::new();
    assert_eq!(svc.restore("crash", &dir).unwrap().snapshot_words(), v1, "fully old after mid-shard crash");

    // crash AFTER the manifest is written but before the publish rename:
    // still fully old
    let mut w = SnapshotWriter::begin(&dir, "crash", &cfg, 2).unwrap();
    for i in 0..2 {
        w.write_shard(i, &reg.snapshot_shard(i)).unwrap();
    }
    w.commit_crash_before_publish(4_000, 0).unwrap();
    let svc = FilterService::new();
    assert_eq!(svc.restore("crash", &dir).unwrap().snapshot_words(), v1, "fully old after pre-publish crash");

    // a later writer sweeps the wreckage and publishes v2 atomically
    let mut w = SnapshotWriter::begin(&dir, "crash", &cfg, 2).unwrap();
    for i in 0..2 {
        w.write_shard(i, &reg.snapshot_shard(i)).unwrap();
    }
    w.commit(4_000, 0).unwrap();
    let svc = FilterService::new();
    assert_eq!(svc.restore("crash", &dir).unwrap().snapshot_words(), reg.snapshot_concat(), "fully new after commit");

    // crash BETWEEN the overwrite's two renames: the destination was
    // parked to `.old` and never replaced — the next restore recovers
    // the last committed snapshot instead of finding nothing
    let old = dir.parent().unwrap().join(format!(".{}.old", dir.file_name().unwrap().to_str().unwrap()));
    std::fs::rename(&dir, &old).unwrap();
    let svc = FilterService::new();
    assert_eq!(
        svc.restore("crash", &dir).unwrap().snapshot_words(),
        reg.snapshot_concat(),
        "parked snapshot recovered after an interrupted swap"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---- restart acceptance: ≥2 namespaces, both transports ----

#[test]
fn multi_namespace_restart_restores_over_both_transports() {
    let alpha_cfg = FilterConfig { log2_m_words: 13, ..Default::default() };
    let beta_cfg = FilterConfig { variant: Variant::Bbf, log2_m_words: 12, ..Default::default() };
    let alpha_keys = unique_keys(8_000, 0xAA);
    let beta_keys = unique_keys(2_000, 0xBB);
    let probes = unique_keys(4_000, 0xCC);
    let state = scratch("restart");

    // boot 1: populate a two-tenant catalog and snapshot it
    let boot1 = FilterService::new();
    let alpha = boot1.create_filter("alpha", alpha_cfg, 4).unwrap();
    let beta = boot1.create_filter("beta", beta_cfg, 2).unwrap();
    alpha.add_bulk(&alpha_keys).wait().unwrap();
    beta.add_bulk(&beta_keys).wait().unwrap();
    boot1.snapshot("alpha", &state.join("alpha")).unwrap();
    boot1.snapshot("beta", &state.join("beta")).unwrap();
    let alpha_words = alpha.snapshot_words();
    let beta_words = beta.snapshot_words();
    let alpha_probe_answers = alpha.query_bulk(&probes).wait().unwrap();
    let beta_probe_answers = beta.query_bulk(&probes).wait().unwrap();
    drop(boot1); // the restart

    // boot 2, in-process transport
    let boot2 = FilterService::new();
    let a2 = boot2.restore("alpha", &state.join("alpha")).unwrap();
    let b2 = boot2.restore("beta", &state.join("beta")).unwrap();
    assert_eq!(a2.snapshot_words(), alpha_words, "alpha byte-identical in-process");
    assert_eq!(b2.snapshot_words(), beta_words, "beta byte-identical in-process");
    assert!(a2.query_bulk(&alpha_keys).wait().unwrap().iter().all(|&x| x));
    assert!(b2.query_bulk(&beta_keys).wait().unwrap().iter().all(|&x| x));
    assert_eq!(a2.query_bulk(&probes).wait().unwrap(), alpha_probe_answers, "identical probe answers");
    assert_eq!(b2.query_bulk(&probes).wait().unwrap(), beta_probe_answers);
    assert_eq!(boot2.stats("alpha").unwrap().metrics.adds, 8_000);
    assert_eq!(boot2.stats("beta").unwrap().metrics.adds, 2_000);

    // boot 2', wire transport: restore by name, paths resolve server-side
    let catalog = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&catalog), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    let ra = client.restore("alpha", state.join("alpha").to_str().unwrap()).unwrap();
    let rb = client.restore("beta", state.join("beta").to_str().unwrap()).unwrap();
    assert_eq!(client.list_filters().unwrap(), vec!["alpha".to_string(), "beta".to_string()]);
    assert!(ra.query_bulk(&alpha_keys).wait().unwrap().iter().all(|&x| x), "no false negatives over the wire");
    assert_eq!(ra.query_bulk(&probes).wait().unwrap(), alpha_probe_answers, "identical answers over the wire");
    assert_eq!(rb.query_bulk(&probes).wait().unwrap(), beta_probe_answers);
    assert_eq!(ra.stats().unwrap().metrics.adds, 8_000, "seeded key counters travel the wire");
    // byte identity checked against the server-side catalog
    assert_eq!(catalog.handle("alpha").unwrap().snapshot_words(), alpha_words, "alpha byte-identical over wire");
    assert_eq!(catalog.handle("beta").unwrap().snapshot_words(), beta_words, "beta byte-identical over wire");

    // a remote snapshot of the restored namespace round-trips too
    let resnap = scratch("resnap");
    client.snapshot("alpha", resnap.to_str().unwrap()).unwrap();
    let boot3 = FilterService::new();
    assert_eq!(boot3.restore("alpha", &resnap).unwrap().snapshot_words(), alpha_words, "second generation identical");

    std::fs::remove_dir_all(&state).ok();
    std::fs::remove_dir_all(&resnap).ok();
}

// ---- typed admin errors around the lifecycle ----

#[test]
fn restore_lifecycle_errors_are_typed() {
    let dir = scratch("lifecycle");
    let service = FilterService::new();
    let cfg = FilterConfig { log2_m_words: 10, ..Default::default() };
    service.create_filter("live", cfg, 1).unwrap();
    service.snapshot("live", &dir).unwrap();

    // restore onto a live name: FilterExists, namespace untouched
    assert_eq!(service.restore("live", &dir).unwrap_err(), GbfError::FilterExists("live".into()));
    // snapshot of a missing namespace: NoSuchFilter
    assert_eq!(service.snapshot("ghost", &dir).unwrap_err(), GbfError::NoSuchFilter("ghost".into()));
    // restore from nowhere: SnapshotCorrupt
    assert!(matches!(
        service.restore("fresh", &scratch("nowhere")),
        Err(GbfError::SnapshotCorrupt(_))
    ));
    // invalid namespace name is rejected before disk is touched
    assert!(matches!(service.restore("bad:name", &dir), Err(GbfError::InvalidConfig(_))));

    // a snapshot may be restored under a DIFFERENT name (migration)
    let renamed = service.restore("live-copy", &dir).unwrap();
    assert_eq!(renamed.name(), "live-copy");
    assert_eq!(renamed.snapshot_words(), service.handle("live").unwrap().snapshot_words());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_handles_fail_after_restore_replaces_the_instance() {
    let dir = scratch("stale");
    let service = FilterService::new();
    let cfg = FilterConfig { log2_m_words: 11, ..Default::default() };
    let old = service.create_filter("ns", cfg, 2).unwrap();
    old.add_bulk(&unique_keys(500, 5)).wait().unwrap();
    service.snapshot("ns", &dir).unwrap();
    service.drop_filter("ns").unwrap();
    let fresh = service.restore("ns", &dir).unwrap();
    // the pre-restore handle pins the dead instance
    assert!(!old.is_live());
    assert_eq!(old.query(1).wait().unwrap_err(), GbfError::NoSuchFilter("ns".into()));
    assert_eq!(old.add(1).wait().unwrap_err(), GbfError::NoSuchFilter("ns".into()));
    // while the restored instance serves (and is a different instance)
    assert_ne!(old.instance(), fresh.instance(), "restore mints a fresh instance id");
    assert!(fresh.query_bulk(&unique_keys(500, 5)).wait().unwrap().iter().all(|&x| x));
    std::fs::remove_dir_all(&dir).ok();
}
