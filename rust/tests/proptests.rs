//! Property-based tests (infra::prop) over randomized configurations,
//! key sets, and layouts — the invariants the paper's design rests on.

use gbf::filter::params::{FilterConfig, Scheme, Variant};
use gbf::filter::AnyBloom;
use gbf::gpu_sim::{model, Features, Op, Residency, B200};
use gbf::hash::pattern::{BlockMask, ProbePlan, ProbeSet};
use gbf::infra::prop::{check, Gen};

/// Draw a random *valid* filter configuration.
fn arb_config(g: &mut Gen) -> FilterConfig {
    loop {
        let variant = *g.choose(&[Variant::Cbf, Variant::Bbf, Variant::Rbbf, Variant::Sbf, Variant::Csbf]);
        let word_bits = if g.bool() { 64 } else { 32 };
        let block_bits = match variant {
            Variant::Rbbf => word_bits,
            Variant::Cbf => 256,
            _ => (word_bits as u64 * g.pow2(0, 4) as u64).min(1024) as u32,
        };
        let s = (block_bits / word_bits).max(1);
        let k = match variant {
            Variant::Sbf | Variant::Rbbf => s * g.range(1, (48 / s).max(1) as u64) as u32,
            Variant::Csbf => 16,
            _ => g.range(1, 24) as u64 as u32,
        };
        let z = if variant == Variant::Csbf { (g.pow2(0, 3) as u32).min(s).min(16) } else { 1 };
        let cfg = FilterConfig {
            variant,
            word_bits,
            block_bits,
            k: k.min(62),
            z,
            scheme: Scheme::Mult,
            log2_m_words: g.range(8, 14) as u32,
            ..Default::default()
        };
        if cfg.validate().is_ok() {
            return cfg;
        }
    }
}

#[test]
fn prop_no_false_negatives() {
    check("no-false-negatives", 60, |g| {
        let cfg = arb_config(g);
        let filter = AnyBloom::new(cfg).unwrap();
        let keys = g.keys(500);
        filter.bulk_add(&keys, 1);
        assert!(filter.bulk_contains(&keys, 1).iter().all(|&h| h), "{}", cfg.name());
    });
}

#[test]
fn prop_insert_order_and_duplication_invariant() {
    check("order-invariant", 40, |g| {
        let cfg = arb_config(g);
        let keys = g.keys(300);
        let a = AnyBloom::new(cfg).unwrap();
        a.bulk_add(&keys, 1);
        // reversed + duplicated insert produces the identical filter
        let mut shuffled: Vec<u64> = keys.iter().rev().copied().collect();
        shuffled.extend(&keys);
        let b = AnyBloom::new(cfg).unwrap();
        b.bulk_add(&shuffled, 1);
        assert_eq!(a.snapshot(), b.snapshot(), "{}", cfg.name());
    });
}

#[test]
fn prop_probe_geometry() {
    check("probe-geometry", 80, |g| {
        let cfg = arb_config(g);
        let plan = ProbePlan::new(&cfg);
        let mut probes = ProbeSet::default();
        for _ in 0..50 {
            let key = g.u64();
            plan.gen_probes(key, &mut probes);
            assert_eq!(probes.len, cfg.words_per_key() as usize);
            let mut bits = 0u32;
            for (w, m) in probes.iter() {
                assert!(w < cfg.m_words());
                assert_ne!(m, 0);
                if cfg.word_bits == 32 {
                    assert_eq!(m >> 32, 0);
                }
                bits += m.count_ones();
            }
            assert!(bits >= 1 && bits <= cfg.k);
            if cfg.is_blocked() {
                let s = cfg.s() as u64;
                let blk = probes.words[0] / s;
                assert!(probes.iter().all(|(w, _)| w / s == blk), "stay in block");
            }
        }
    });
}

#[test]
fn prop_block_mask_equals_probe_set() {
    check("block-mask-equiv", 60, |g| {
        let cfg = arb_config(g);
        if !cfg.is_blocked() {
            return;
        }
        let plan = ProbePlan::new(&cfg);
        let (mut probes, mut bm) = (ProbeSet::default(), BlockMask::default());
        for _ in 0..30 {
            let key = g.u64();
            plan.gen_probes(key, &mut probes);
            plan.gen_block_mask(key, &mut bm);
            let mut dense = [0u64; 32];
            for (w, m) in probes.iter() {
                dense[(w - bm.block_word0) as usize] |= m;
            }
            assert_eq!(&dense[..bm.s], &bm.masks[..bm.s]);
        }
    });
}

#[test]
fn prop_layouts_never_change_filter_semantics() {
    // Θ/Φ are perf knobs only: the model may differ, the bits may not.
    check("layout-semantics", 40, |g| {
        let base = arb_config(g);
        if !base.is_blocked() {
            return;
        }
        let s = base.s();
        let theta = (g.pow2(0, 5) as u32).min(s);
        let phi = (g.pow2(0, 5) as u32).min(s / theta).max(1);
        let cfg = FilterConfig { theta, phi, ..base };
        if cfg.validate().is_err() {
            return;
        }
        let keys = g.keys(200);
        let a = AnyBloom::new(base).unwrap();
        let b = AnyBloom::new(cfg).unwrap();
        a.bulk_add(&keys, 1);
        b.bulk_add(&keys, 1);
        assert_eq!(a.snapshot(), b.snapshot());
        let queries = g.keys(200);
        assert_eq!(a.bulk_contains(&queries, 1), b.bulk_contains(&queries, 1));
    });
}

#[test]
fn prop_model_outputs_finite_and_positive() {
    check("model-sane", 100, |g| {
        let cfg = arb_config(g);
        let theta = (g.pow2(0, 5) as u32).min(cfg.s().max(1));
        let phi = model::max_phi(&cfg, theta);
        let residency = if g.bool() { Residency::L2 } else { Residency::Dram };
        let op = if g.bool() { Op::Contains } else { Op::Add };
        let feats = Features {
            mult_hash: g.bool(),
            horizontal_vec: g.bool(),
            adaptive_coop: g.bool(),
        };
        let cfg = if cfg.variant == Variant::Cbf { cfg } else { cfg };
        let theta = if cfg.variant == Variant::Cbf { 1 } else { theta };
        let p = model::predict(&cfg, op, theta, phi, residency, &B200, feats);
        assert!(p.gelems_per_sec.is_finite() && p.gelems_per_sec > 0.0, "{}", cfg.name());
        assert!(p.sector_transactions >= 0.9);
        assert!(p.instructions > 5.0);
        // never above the physically meaningful ceilings
        assert!(p.gelems_per_sec < 500.0, "{}: {}", cfg.name(), p.gelems_per_sec);
    });
}

#[test]
fn prop_merge_union_semantics() {
    check("merge-union", 30, |g| {
        let cfg = arb_config(g);
        if cfg.word_bits != 64 {
            return;
        }
        let (ka, kb) = (g.keys(200), g.keys(200));
        let a = AnyBloom::new(cfg).unwrap();
        let b = AnyBloom::new(cfg).unwrap();
        a.bulk_add(&ka, 1);
        b.bulk_add(&kb, 1);
        // union via word-level OR
        let mut want: Vec<u64> = a.snapshot();
        for (w, o) in want.iter_mut().zip(b.snapshot()) {
            *w |= o;
        }
        let u = AnyBloom::new(cfg).unwrap();
        u.bulk_add(&ka, 1);
        u.bulk_add(&kb, 1);
        assert_eq!(u.snapshot(), want);
    });
}
