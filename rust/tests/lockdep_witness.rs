//! Runtime lockdep witness (ISSUE 7 tentpole, runtime half): deliberate
//! lock-order inversions and condvar misuse through the real
//! `infra::sync` classed primitives must panic the witness in debug
//! builds — naming both classes and both acquisition sites — and must
//! cost nothing in release builds, where the witness is compiled out.
//!
//! These are the runtime twins of the static-pass fixture tests in
//! `xtask` (`static_pass_catches_seeded_inversion`): the same seeded
//! inversion, caught by both halves of the analyzer. Class names here
//! are `w7.*`, which keeps them out of the product hierarchy in
//! `LOCKS.md` (the lockgraph workload never runs this file).

#[cfg(debug_assertions)]
use std::panic::{catch_unwind, AssertUnwindSafe};

use gbf::infra::lockdep;
#[cfg(debug_assertions)]
use gbf::infra::sync::Condvar;
use gbf::infra::sync::Mutex;

/// Panic payloads from the witness are formatted `String`s.
#[cfg(debug_assertions)]
fn payload(err: Box<dyn std::any::Any + Send>) -> String {
    err.downcast_ref::<String>().expect("witness panics carry a String payload").clone()
}

#[test]
#[cfg(debug_assertions)]
fn witness_is_active_in_debug_builds() {
    assert!(lockdep::is_active(), "debug_assertions build must carry the witness");
}

#[test]
#[cfg(debug_assertions)]
fn witness_records_edges_with_call_sites() {
    let x = Mutex::new_class("w7.edge.x", ());
    let y = Mutex::new_class("w7.edge.y", ());
    let gx = x.lock().unwrap();
    let gy = y.lock().unwrap();
    drop(gy);
    drop(gx);
    let edges = lockdep::observed_edges();
    let edge = edges
        .iter()
        .find(|e| e.from == "w7.edge.x" && e.to == "w7.edge.y")
        .expect("nested acquisition must fold an observed edge");
    assert!(
        edge.from_site.contains("lockdep_witness.rs") && edge.to_site.contains("lockdep_witness.rs"),
        "track_caller sites must point at this file: {} -> {}",
        edge.from_site,
        edge.to_site
    );
}

/// The seeded inversion: establish `a -> b`, then acquire in the other
/// order. The witness must panic on the second acquisition — before any
/// thread can block — naming both classes and both sites.
#[test]
#[cfg(debug_assertions)]
fn inversion_panics_naming_both_classes_and_sites() {
    let a = Mutex::new_class("w7.inv.a", ());
    let b = Mutex::new_class("w7.inv.b", ());
    {
        let _ga = a.lock().unwrap();
        let _gb = b.lock().unwrap();
    }
    let err = catch_unwind(AssertUnwindSafe(|| {
        let _gb = b.lock().unwrap();
        let _ga = a.lock().unwrap();
    }))
    .expect_err("lock-order inversion must panic the witness");
    let msg = payload(err);
    assert!(msg.contains("lockdep: lock-order cycle"), "{msg}");
    assert!(msg.contains("\"w7.inv.a\"") && msg.contains("\"w7.inv.b\""), "both classes named: {msg}");
    assert!(msg.contains("lockdep_witness.rs"), "acquisition sites name this file: {msg}");
}

/// Waiting on a condvar while holding a lock of a *different* class is a
/// latent deadlock (nothing can wake the waiter if the signaller needs
/// that lock); the witness panics before parking.
#[test]
#[cfg(debug_assertions)]
fn wait_while_holding_foreign_lock_panics() {
    let outer = Mutex::new_class("w7.wait.outer", ());
    let m = Mutex::new_class("w7.wait.m", false);
    let cv = Condvar::new_class("w7.wait.cv");
    let err = catch_unwind(AssertUnwindSafe(|| {
        let _held = outer.lock().unwrap();
        let guard = m.lock().unwrap();
        let _guard = cv.wait(guard).unwrap();
    }))
    .expect_err("condvar wait while holding another lock class must panic");
    let msg = payload(err);
    assert!(msg.contains("blocking wait on condvar class \"w7.wait.cv\""), "{msg}");
    assert!(msg.contains("\"w7.wait.outer\""), "the held class is named: {msg}");
}

/// Waiting with only the condvar's own guard held is the legitimate
/// pattern and must stay silent.
#[test]
#[cfg(debug_assertions)]
fn wait_with_only_own_guard_is_silent() {
    use std::time::Duration;
    let m = Mutex::new_class("w7.ok.m", false);
    let cv = Condvar::new_class("w7.ok.cv");
    let guard = m.lock().unwrap();
    let (_guard, timeout) = cv.wait_timeout(guard, Duration::from_millis(1)).unwrap();
    assert!(timeout.timed_out(), "nothing signals: the wait must simply time out");
}

/// Release builds compile the witness out entirely: the same inversion
/// runs silently and the observation API answers empty.
#[test]
#[cfg(not(debug_assertions))]
fn release_build_witness_is_silent() {
    assert!(!lockdep::is_active(), "release build must not carry the witness");
    let a = Mutex::new_class("w7.rel.a", ());
    let b = Mutex::new_class("w7.rel.b", ());
    {
        let _ga = a.lock().unwrap();
        let _gb = b.lock().unwrap();
    }
    {
        let _gb = b.lock().unwrap();
        let _ga = a.lock().unwrap();
    }
    assert!(lockdep::observed_edges().is_empty(), "release witness observes nothing");
}
