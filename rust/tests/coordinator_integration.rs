//! Filter-service integration: the multi-tenant admin plane
//! (create/drop/list/stats), the ticket-based data plane, namespace
//! isolation under concurrency, per-shard metrics, mixed workloads, and
//! the PJRT backend when artifacts are available.

use std::time::Duration;

use gbf::coordinator::{BatchPolicy, FilterBackend, FilterService, FilterSpec, GbfError, PjrtBackend};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};
use gbf::workload::zipf::Zipf;

fn cfg(log2_m_words: u32) -> FilterConfig {
    FilterConfig { log2_m_words, ..Default::default() }
}

fn spec(log2_m_words: u32, shards: usize, max_batch: usize, wait_us: u64) -> FilterSpec {
    FilterSpec {
        config: cfg(log2_m_words),
        shards,
        policy: BatchPolicy { max_batch, max_wait: Duration::from_micros(wait_us) },
    }
}

fn native_service(entries: &[(&str, FilterSpec)]) -> FilterService {
    let service = FilterService::new();
    for (name, s) in entries {
        service.create_filter_spec(name, s.clone()).unwrap();
    }
    service
}

// ---- acceptance: >= 2 concurrently-live namespaces, independent configs,
// ticket and blocking paths agreeing, no implicit filter anywhere ----

#[test]
fn two_live_namespaces_with_independent_configs() {
    let service = native_service(&[("hot", spec(15, 4, 1024, 150)), ("cold", spec(13, 1, 256, 100))]);
    let hot = service.handle("hot").unwrap();
    let cold = service.handle("cold").unwrap();
    assert_eq!(hot.num_shards(), 4);
    assert_eq!(cold.num_shards(), 1);
    assert_eq!(hot.filter_config().log2_m_words, 15);
    assert_eq!(cold.filter_config().log2_m_words, 13);

    let hot_keys = unique_keys(20_000, 1);
    let cold_keys = unique_keys(2_000, 2);
    // pipelined: both namespaces ingesting at once
    let t1 = hot.add_bulk(&hot_keys);
    let t2 = cold.add_bulk(&cold_keys);
    t1.wait().unwrap();
    t2.wait().unwrap();

    // ticket-based and blocking paths must give identical answers
    let probe: Vec<u64> = hot_keys.iter().chain(unique_keys(5_000, 3).iter()).copied().collect();
    let ticket_first = hot.query_bulk(&probe); // submitted, waited later
    let blocking = hot.query_bulk(&probe).wait().unwrap(); // "blocking" = wait immediately
    let ticketed = ticket_first.wait().unwrap();
    assert_eq!(ticketed, blocking);
    assert!(ticketed[..20_000].iter().all(|&h| h), "no false negatives");

    // per-namespace counters: each tenant saw exactly its own traffic
    let hot_stats = service.stats("hot").unwrap();
    let cold_stats = service.stats("cold").unwrap();
    assert_eq!(hot_stats.metrics.adds, 20_000);
    assert_eq!(hot_stats.metrics.queries, 2 * probe.len() as u64);
    assert_eq!(cold_stats.metrics.adds, 2_000);
    assert_eq!(cold_stats.metrics.queries, 0);
}

// ---- admin plane ----

#[test]
fn create_drop_lifecycle() {
    let service = FilterService::new();
    assert!(service.list_filters().is_empty());
    service.create_filter("a", cfg(12), 2).unwrap();
    service.create_filter("b", cfg(12), 1).unwrap();
    assert_eq!(service.list_filters(), vec!["a".to_string(), "b".to_string()]);
    service.drop_filter("a").unwrap();
    assert_eq!(service.list_filters(), vec!["b".to_string()]);
    // the name is reusable with a different geometry
    let a2 = service.create_filter("a", cfg(14), 4).unwrap();
    assert_eq!(a2.num_shards(), 4);
    a2.add_bulk(&[1, 2, 3]).wait().unwrap();
    assert!(a2.query_bulk(&[1, 2, 3]).wait().unwrap().iter().all(|&h| h));
}

#[test]
fn duplicate_name_rejected() {
    let service = FilterService::new();
    service.create_filter("dup", cfg(12), 1).unwrap();
    match service.create_filter("dup", cfg(12), 1) {
        Err(GbfError::FilterExists(name)) => assert_eq!(name, "dup"),
        other => panic!("expected FilterExists, got {other:?}"),
    }
    // the original namespace is untouched by the failed create
    let h = service.handle("dup").unwrap();
    h.add(7).wait().unwrap();
    assert!(h.query(7).wait().unwrap());
}

#[test]
fn dropped_namespace_yields_no_such_filter() {
    let service = FilterService::new();
    let h = service.create_filter("gone", cfg(12), 2).unwrap();
    h.add_bulk(&unique_keys(1_000, 4)).wait().unwrap();
    service.drop_filter("gone").unwrap();

    // every plane answers NoSuchFilter for the dropped name
    assert_eq!(service.handle("gone").unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(service.stats("gone").unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(service.drop_filter("gone").unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    // including operations on handles that predate the drop
    assert!(!h.is_live());
    assert_eq!(h.query_bulk(&[1]).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(h.add_bulk(&[1]).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(h.add(1).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(h.query(1).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
}

// ---- namespace isolation under concurrency (timing-free: asserted via
// per-namespace op counters, not wall clocks) ----

#[test]
fn concurrent_handles_to_distinct_namespaces_do_not_serialize() {
    const TENANTS: usize = 6;
    const KEYS_PER_TENANT: usize = 4_000;
    let service = FilterService::new();
    let mut names = Vec::new();
    for t in 0..TENANTS {
        let name = format!("tenant{t}");
        service.create_filter(&name, cfg(14), 2).unwrap();
        names.push(name);
    }
    std::thread::scope(|scope| {
        for (t, name) in names.iter().enumerate() {
            let handle = service.handle(name).unwrap();
            scope.spawn(move || {
                let keys = unique_keys(KEYS_PER_TENANT, 100 + t as u64);
                handle.add_bulk(&keys).wait().unwrap();
                let hits = handle.query_bulk(&keys).wait().unwrap();
                assert!(hits.iter().all(|&h| h));
            });
        }
    });
    // every namespace processed exactly its own tenant's ops — nothing
    // leaked into a shared queue, nothing was double-counted
    for name in &names {
        let stats = service.stats(name).unwrap();
        assert_eq!(stats.metrics.adds, KEYS_PER_TENANT as u64, "{name}");
        assert_eq!(stats.metrics.queries, KEYS_PER_TENANT as u64, "{name}");
        assert_eq!(stats.queue_depth, 0, "{name} drained");
    }
}

// ---- per-shard metrics through the stats admin call ----

#[test]
fn per_shard_stats_surface_through_stats() {
    let service = native_service(&[("sharded", spec(15, 4, 4096, 200))]);
    let h = service.handle("sharded").unwrap();
    let keys = unique_keys(40_000, 5);
    h.add_bulk(&keys).wait().unwrap();
    h.query_bulk(&keys).wait().unwrap();
    let stats = service.stats("sharded").unwrap();
    assert_eq!(stats.num_shards, 4);
    assert_eq!(stats.shards.len(), 4);
    let total: u64 = stats.shards.iter().map(|s| s.keys).sum();
    assert_eq!(total, 80_000, "per-shard key counters cover every op exactly once");
    for s in &stats.shards {
        assert!(s.keys > 0, "uniform routing reaches shard {}", s.shard);
        assert!(s.jobs > 0);
        assert!(s.fill_ratio > 0.0);
    }
    // the shutdown report renders one line per shard
    let report = stats.report();
    assert_eq!(report.matches("shard ").count(), 4, "{report}");
}

// ---- ticket mechanics ----

#[test]
fn ticket_poll_wait_timeout_and_ready() {
    let service = native_service(&[("t", spec(14, 2, 512, 100))]);
    let h = service.handle("t").unwrap();
    let keys = unique_keys(10_000, 6);
    // wait_timeout path agrees with plain wait
    match h.add_bulk(&keys).wait_timeout(Duration::from_secs(10)) {
        Ok(r) => r.unwrap(),
        Err(_) => panic!("10s is plenty for 10k adds"),
    }
    let t = h.query_bulk(&keys);
    let hits = t.wait().unwrap();
    assert!(hits.iter().all(|&h| h));
    // a timed-out wait hands the ticket back intact and it stays waitable
    let t2 = h.query_bulk(&keys);
    let hits2 = match t2.wait_timeout(Duration::from_nanos(1)) {
        Ok(r) => r.unwrap(), // already done — also a valid outcome
        Err(again) => again.wait().unwrap(),
    };
    assert_eq!(hits, hits2);
    // polling observes completion without consuming the ticket
    let t3 = h.query_bulk(&keys[..100]);
    while !t3.is_ready() {
        std::thread::yield_now();
    }
    assert!(t3.wait().unwrap().iter().all(|&b| b));
    // empty submissions resolve instantly
    let empty = h.query_bulk(&[]);
    assert!(empty.is_ready());
    assert!(empty.wait().unwrap().is_empty());
}

// ---- retained workload coverage from the old single-filter suite ----

#[test]
fn mixed_interleaved_workload_is_consistent() {
    let service = native_service(&[("waves", spec(15, 4, 1024, 150))]);
    let c = service.handle("waves").unwrap();
    let keys = unique_keys(20_000, 1);
    // interleave adds and queries in waves; earlier waves must stay visible
    for wave in 0..4 {
        let slice = &keys[wave * 5_000..(wave + 1) * 5_000];
        c.add_bulk(slice).wait().unwrap();
        for prev in 0..=wave {
            let check = &keys[prev * 5_000..prev * 5_000 + 500];
            assert!(c.query_bulk(check).wait().unwrap().iter().all(|&h| h), "wave {wave} prev {prev}");
        }
    }
    let m = service.stats("waves").unwrap().metrics;
    assert_eq!(m.adds, 20_000);
    assert!(m.batches > 0 && m.mean_batch_size >= 1.0);
}

#[test]
fn zipf_hot_key_traffic() {
    let service = native_service(&[("zipf", spec(15, 2, 512, 100))]);
    let c = service.handle("zipf").unwrap();
    let universe = unique_keys(5_000, 2);
    c.add_bulk(&universe).wait().unwrap();
    let mut z = Zipf::new(universe.len() as u64, 1.3, 7);
    let trace = z.trace(&universe, 30_000);
    let hits = c.query_bulk(&trace).wait().unwrap();
    assert!(hits.iter().all(|&h| h), "hot keys must always hit");
}

#[test]
fn fpr_preserved_through_sharded_service() {
    // sharding must not inflate FPR beyond the single-filter rate by more
    // than noise (each shard is a smaller filter at the same load factor)
    let service = native_service(&[("fpr", spec(15, 4, 4096, 200))]);
    let c = service.handle("fpr").unwrap();
    let (ins, qry) = disjoint_key_sets(80_000, 40_000, 3);
    c.add_bulk(&ins).wait().unwrap();
    let fp = c.query_bulk(&qry).wait().unwrap().iter().filter(|&&h| h).count();
    let fpr = fp as f64 / qry.len() as f64;
    assert!(fpr < 0.05, "service fpr {fpr}");
}

#[test]
fn heavy_concurrency_stress_on_one_namespace() {
    let service = native_service(&[("stress", spec(15, 4, 2048, 200))]);
    std::thread::scope(|scope| {
        for t in 0..16u64 {
            let handle = service.handle("stress").unwrap();
            scope.spawn(move || {
                let keys = unique_keys(4_000, 50 + t);
                handle.add_bulk(&keys).wait().unwrap();
                let hits = handle.query_bulk(&keys).wait().unwrap();
                assert!(hits.iter().all(|&h| h));
            });
        }
    });
    assert_eq!(service.stats("stress").unwrap().metrics.adds, 64_000);
}

// ---- PJRT namespaces (skip without artifacts) ----

#[test]
fn pjrt_namespace_reports_single_state_placement() {
    let Ok(manifest) = Manifest::load(&default_artifact_dir()) else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let actor = EngineActor::spawn_with_manifest(manifest.clone()).unwrap();
    let client = actor.client();
    let config = FilterConfig::default();
    let service = FilterService::new();
    // ask for 4 shards; the single-state PJRT backend places 1 — visible
    // through stats instead of a stderr warning
    let s = FilterSpec {
        config,
        shards: 4,
        policy: BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(300) },
    };
    service
        .create_filter_with("pjrt", s, move |_| {
            Ok(Box::new(PjrtBackend::new(client, &manifest, config, "pallas")?) as Box<dyn FilterBackend>)
        })
        .unwrap();
    let stats = service.stats("pjrt").unwrap();
    assert_eq!(stats.backend, "pjrt");
    assert_eq!(stats.requested_shards, 4);
    assert_eq!(stats.num_shards, 1, "single-state placement is introspectable");
    assert!(stats.shards.is_empty(), "no per-shard rows for a single-state backend");
    assert!(stats.report().contains("requested 4"), "{}", stats.report());

    let h = service.handle("pjrt").unwrap();
    let keys = unique_keys(6_000, 5);
    h.add_bulk(&keys).wait().unwrap();
    assert!(h.query_bulk(&keys).wait().unwrap().iter().all(|&h| h));
    let (_, absent) = disjoint_key_sets(1, 6_000, 6);
    let fp = h.query_bulk(&absent).wait().unwrap().iter().filter(|&&h| h).count();
    assert!(fp < 600, "pjrt fpr too high: {fp}/6000");
}

#[test]
fn variant_diversity_across_namespaces() {
    // independent configs really are independent: different variants and
    // geometries live side by side in one catalog
    let service = FilterService::new();
    let entries = [
        ("sbf", FilterConfig { variant: Variant::Sbf, log2_m_words: 13, ..Default::default() }),
        ("cbf", FilterConfig { variant: Variant::Cbf, log2_m_words: 12, ..Default::default() }),
        ("bbf", FilterConfig { variant: Variant::Bbf, log2_m_words: 14, ..Default::default() }),
    ];
    for (name, config) in &entries {
        service.create_filter(name, *config, 2).unwrap();
    }
    let keys = unique_keys(3_000, 9);
    let handles: Vec<_> = entries.iter().map(|(n, _)| service.handle(n).unwrap()).collect();
    let tickets: Vec<_> = handles.iter().map(|h| h.add_bulk(&keys)).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    for h in &handles {
        assert!(h.query_bulk(&keys).wait().unwrap().iter().all(|&hit| hit), "{}", h.name());
    }
    assert_eq!(service.list_filters().len(), 3);
}
