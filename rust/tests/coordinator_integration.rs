//! Coordinator integration: batching policy effects, backpressure,
//! mixed workloads, metrics sanity, and the PJRT backend when available.

use std::sync::Arc;
use std::time::Duration;

use gbf::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, FilterBackend, NativeBackend, PjrtBackend, RequestOp,
};
use gbf::filter::params::FilterConfig;
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};
use gbf::workload::zipf::Zipf;

fn native(shards: usize, max_batch: usize, wait_us: u64) -> Coordinator {
    Coordinator::new(
        CoordinatorConfig {
            num_shards: shards,
            policy: BatchPolicy { max_batch, max_wait: Duration::from_micros(wait_us) },
        },
        |num_shards| {
            Ok(Box::new(NativeBackend::new(
                FilterConfig { log2_m_words: 15, ..Default::default() },
                num_shards,
            )?) as Box<dyn FilterBackend>)
        },
    )
    .unwrap()
}

#[test]
fn mixed_interleaved_workload_is_consistent() {
    let c = native(4, 1024, 150);
    let keys = unique_keys(20_000, 1);
    // interleave adds and queries in waves; earlier waves must stay visible
    for wave in 0..4 {
        let slice = &keys[wave * 5_000..(wave + 1) * 5_000];
        c.add_blocking(slice).unwrap();
        for prev in 0..=wave {
            let check = &keys[prev * 5_000..prev * 5_000 + 500];
            assert!(c.query_blocking(check).unwrap().iter().all(|&h| h), "wave {wave} prev {prev}");
        }
    }
    let m = c.metrics();
    assert_eq!(m.adds, 20_000);
    assert!(m.batches > 0 && m.mean_batch_size >= 1.0);
}

#[test]
fn zipf_hot_key_traffic() {
    let c = native(2, 512, 100);
    let universe = unique_keys(5_000, 2);
    c.add_blocking(&universe).unwrap();
    let mut z = Zipf::new(universe.len() as u64, 1.3, 7);
    let trace = z.trace(&universe, 30_000);
    let hits = c.query_blocking(&trace).unwrap();
    assert!(hits.iter().all(|&h| h), "hot keys must always hit");
}

#[test]
fn fpr_preserved_through_sharded_service() {
    // sharding must not inflate FPR beyond the single-filter rate by more
    // than noise (each shard is a smaller filter at the same load factor)
    let c = native(4, 4096, 200);
    let (ins, qry) = disjoint_key_sets(80_000, 40_000, 3);
    c.add_blocking(&ins).unwrap();
    let fp = c.query_blocking(&qry).unwrap().iter().filter(|&&h| h).count();
    let fpr = fp as f64 / qry.len() as f64;
    assert!(fpr < 0.05, "service fpr {fpr}");
}

#[test]
fn single_request_latency_bounded_by_deadline() {
    let c = native(1, 1 << 20, 2_000); // huge batch, 2ms deadline
    let t0 = std::time::Instant::now();
    let rx = c.submit(RequestOp::Add, 42);
    rx.recv().unwrap().unwrap();
    let dt = t0.elapsed();
    assert!(dt < Duration::from_millis(500), "deadline flush too slow: {dt:?}");
}

#[test]
fn queue_depth_drains() {
    let c = native(2, 256, 100);
    let keys = unique_keys(10_000, 4);
    c.add_blocking(&keys).unwrap();
    // after blocking calls return, queues must be empty
    assert_eq!(c.queue_depth(), 0);
}

#[test]
fn heavy_concurrency_stress() {
    let c = Arc::new(native(4, 2048, 200));
    std::thread::scope(|scope| {
        for t in 0..16u64 {
            let c = Arc::clone(&c);
            scope.spawn(move || {
                let keys = unique_keys(4_000, 50 + t);
                c.add_blocking(&keys).unwrap();
                let hits = c.query_blocking(&keys).unwrap();
                assert!(hits.iter().all(|&h| h));
            });
        }
    });
    assert_eq!(c.metrics().adds, 64_000);
}

#[test]
fn pjrt_backend_through_coordinator() {
    let Ok(manifest) = Manifest::load(&default_artifact_dir()) else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let actor = EngineActor::spawn_with_manifest(manifest.clone()).unwrap();
    let client = actor.client();
    let cfg = FilterConfig::default();
    let c = Coordinator::new(
        CoordinatorConfig {
            // one filter state: PJRT shard placement is a ROADMAP item
            num_shards: 1,
            policy: BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(300) },
        },
        move |_| {
            Ok(Box::new(PjrtBackend::new(client.clone(), &manifest, cfg, "pallas")?)
                as Box<dyn FilterBackend>)
        },
    )
    .unwrap();
    assert_eq!(c.backend_name(), "pjrt");
    let keys = unique_keys(6_000, 5);
    c.add_blocking(&keys).unwrap();
    assert!(c.query_blocking(&keys).unwrap().iter().all(|&h| h));
    let (_, absent) = disjoint_key_sets(1, 6_000, 6);
    let fp = c.query_blocking(&absent).unwrap().iter().filter(|&&h| h).count();
    assert!(fp < 600, "pjrt fpr too high: {fp}/6000");
}
