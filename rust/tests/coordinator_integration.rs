//! Filter-service integration: the multi-tenant admin plane
//! (create/drop/list/stats), the ticket-based data plane, namespace
//! isolation under concurrency, per-shard metrics, mixed workloads, the
//! PJRT backend when artifacts are available — and **transport
//! equivalence**: the same generic test body, written against
//! `dyn FilterApi`, passes over the in-process `FilterService` and a
//! loopback `RemoteFilterService` with identical answers and identical
//! typed errors — including the durable `snapshot`/`restore` pair
//! (whose torture suite lives in `rust/tests/persistence.rs`).

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use gbf::coordinator::{
    BatchPolicy, FilterBackend, FilterService, FilterSpec, GbfError, PjrtBackend,
    RemoteFilterService, WireServer,
};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};
use gbf::workload::zipf::Zipf;

/// Spec builders and the transport-agnostic `drive_api` acceptance
/// driver live in `tests/common/` so the cluster suite
/// (`cluster_integration.rs`) can run the UNMODIFIED body over the
/// replicated front end.
mod common;
use common::{cfg, drive_api, spec};

fn native_service(entries: &[(&str, FilterSpec)]) -> FilterService {
    let service = FilterService::new();
    for (name, s) in entries {
        service.create_filter_spec(name, s.clone()).unwrap();
    }
    service
}

// ---- acceptance: >= 2 concurrently-live namespaces, independent configs,
// ticket and blocking paths agreeing, no implicit filter anywhere ----

#[test]
fn two_live_namespaces_with_independent_configs() {
    let service = native_service(&[("hot", spec(15, 4, 1024, 150)), ("cold", spec(13, 1, 256, 100))]);
    let hot = service.handle("hot").unwrap();
    let cold = service.handle("cold").unwrap();
    assert_eq!(hot.num_shards(), 4);
    assert_eq!(cold.num_shards(), 1);
    assert_eq!(hot.filter_config().log2_m_words, 15);
    assert_eq!(cold.filter_config().log2_m_words, 13);

    let hot_keys = unique_keys(20_000, 1);
    let cold_keys = unique_keys(2_000, 2);
    // pipelined: both namespaces ingesting at once
    let t1 = hot.add_bulk(&hot_keys);
    let t2 = cold.add_bulk(&cold_keys);
    t1.wait().unwrap();
    t2.wait().unwrap();

    // ticket-based and blocking paths must give identical answers
    let probe: Vec<u64> = hot_keys.iter().chain(unique_keys(5_000, 3).iter()).copied().collect();
    let ticket_first = hot.query_bulk(&probe); // submitted, waited later
    let blocking = hot.query_bulk(&probe).wait().unwrap(); // "blocking" = wait immediately
    let ticketed = ticket_first.wait().unwrap();
    assert_eq!(ticketed, blocking);
    assert!(ticketed[..20_000].iter().all(|&h| h), "no false negatives");

    // per-namespace counters: each tenant saw exactly its own traffic
    let hot_stats = service.stats("hot").unwrap();
    let cold_stats = service.stats("cold").unwrap();
    assert_eq!(hot_stats.metrics.adds, 20_000);
    assert_eq!(hot_stats.metrics.queries, 2 * probe.len() as u64);
    assert_eq!(cold_stats.metrics.adds, 2_000);
    assert_eq!(cold_stats.metrics.queries, 0);
}

// ---- admin plane ----

#[test]
fn create_drop_lifecycle() {
    let service = FilterService::new();
    assert!(service.list_filters().is_empty());
    service.create_filter("a", cfg(12), 2).unwrap();
    service.create_filter("b", cfg(12), 1).unwrap();
    assert_eq!(service.list_filters(), vec!["a".to_string(), "b".to_string()]);
    service.drop_filter("a").unwrap();
    assert_eq!(service.list_filters(), vec!["b".to_string()]);
    // the name is reusable with a different geometry
    let a2 = service.create_filter("a", cfg(14), 4).unwrap();
    assert_eq!(a2.num_shards(), 4);
    a2.add_bulk(&[1, 2, 3]).wait().unwrap();
    assert!(a2.query_bulk(&[1, 2, 3]).wait().unwrap().iter().all(|&h| h));
}

#[test]
fn duplicate_name_rejected() {
    let service = FilterService::new();
    service.create_filter("dup", cfg(12), 1).unwrap();
    match service.create_filter("dup", cfg(12), 1) {
        Err(GbfError::FilterExists(name)) => assert_eq!(name, "dup"),
        other => panic!("expected FilterExists, got {other:?}"),
    }
    // the original namespace is untouched by the failed create
    let h = service.handle("dup").unwrap();
    h.add(7).wait().unwrap();
    assert!(h.query(7).wait().unwrap());
}

#[test]
fn dropped_namespace_yields_no_such_filter() {
    let service = FilterService::new();
    let h = service.create_filter("gone", cfg(12), 2).unwrap();
    h.add_bulk(&unique_keys(1_000, 4)).wait().unwrap();
    service.drop_filter("gone").unwrap();

    // every plane answers NoSuchFilter for the dropped name
    assert_eq!(service.handle("gone").unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(service.stats("gone").unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(service.drop_filter("gone").unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    // including operations on handles that predate the drop
    assert!(!h.is_live());
    assert_eq!(h.query_bulk(&[1]).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(h.add_bulk(&[1]).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(h.add(1).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
    assert_eq!(h.query(1).wait().unwrap_err(), GbfError::NoSuchFilter("gone".into()));
}

// ---- namespace isolation under concurrency (timing-free: asserted via
// per-namespace op counters, not wall clocks) ----

#[test]
fn concurrent_handles_to_distinct_namespaces_do_not_serialize() {
    const TENANTS: usize = 6;
    const KEYS_PER_TENANT: usize = 4_000;
    let service = FilterService::new();
    let mut names = Vec::new();
    for t in 0..TENANTS {
        let name = format!("tenant{t}");
        service.create_filter(&name, cfg(14), 2).unwrap();
        names.push(name);
    }
    std::thread::scope(|scope| {
        for (t, name) in names.iter().enumerate() {
            let handle = service.handle(name).unwrap();
            scope.spawn(move || {
                let keys = unique_keys(KEYS_PER_TENANT, 100 + t as u64);
                handle.add_bulk(&keys).wait().unwrap();
                let hits = handle.query_bulk(&keys).wait().unwrap();
                assert!(hits.iter().all(|&h| h));
            });
        }
    });
    // every namespace processed exactly its own tenant's ops — nothing
    // leaked into a shared queue, nothing was double-counted
    for name in &names {
        let stats = service.stats(name).unwrap();
        assert_eq!(stats.metrics.adds, KEYS_PER_TENANT as u64, "{name}");
        assert_eq!(stats.metrics.queries, KEYS_PER_TENANT as u64, "{name}");
        assert_eq!(stats.queue_depth, 0, "{name} drained");
    }
}

// ---- per-shard metrics through the stats admin call ----

#[test]
fn per_shard_stats_surface_through_stats() {
    let service = native_service(&[("sharded", spec(15, 4, 4096, 200))]);
    let h = service.handle("sharded").unwrap();
    let keys = unique_keys(40_000, 5);
    h.add_bulk(&keys).wait().unwrap();
    h.query_bulk(&keys).wait().unwrap();
    let stats = service.stats("sharded").unwrap();
    assert_eq!(stats.num_shards, 4);
    assert_eq!(stats.shards.len(), 4);
    let total: u64 = stats.shards.iter().map(|s| s.keys).sum();
    assert_eq!(total, 80_000, "per-shard key counters cover every op exactly once");
    for s in &stats.shards {
        assert!(s.keys > 0, "uniform routing reaches shard {}", s.shard);
        assert!(s.jobs > 0);
        assert!(s.fill_ratio > 0.0);
    }
    // the shutdown report renders one line per shard
    let report = stats.report();
    assert_eq!(report.matches("shard ").count(), 4, "{report}");
}

// ---- ticket mechanics ----

#[test]
fn ticket_poll_wait_timeout_and_ready() {
    let service = native_service(&[("t", spec(14, 2, 512, 100))]);
    let h = service.handle("t").unwrap();
    let keys = unique_keys(10_000, 6);
    // wait_timeout path agrees with plain wait
    match h.add_bulk(&keys).wait_timeout(Duration::from_secs(10)) {
        Ok(r) => r.unwrap(),
        Err(_) => panic!("10s is plenty for 10k adds"),
    }
    let t = h.query_bulk(&keys);
    let hits = t.wait().unwrap();
    assert!(hits.iter().all(|&h| h));
    // a timed-out wait hands the ticket back intact and it stays waitable
    let t2 = h.query_bulk(&keys);
    let hits2 = match t2.wait_timeout(Duration::from_nanos(1)) {
        Ok(r) => r.unwrap(), // already done — also a valid outcome
        Err(again) => again.wait().unwrap(),
    };
    assert_eq!(hits, hits2);
    // polling observes completion without consuming the ticket
    let t3 = h.query_bulk(&keys[..100]);
    while !t3.is_ready() {
        std::thread::yield_now();
    }
    assert!(t3.wait().unwrap().iter().all(|&b| b));
    // empty submissions resolve instantly
    let empty = h.query_bulk(&[]);
    assert!(empty.is_ready());
    assert!(empty.wait().unwrap().is_empty());
}

// ---- retained workload coverage from the old single-filter suite ----

#[test]
fn mixed_interleaved_workload_is_consistent() {
    let service = native_service(&[("waves", spec(15, 4, 1024, 150))]);
    let c = service.handle("waves").unwrap();
    let keys = unique_keys(20_000, 1);
    // interleave adds and queries in waves; earlier waves must stay visible
    for wave in 0..4 {
        let slice = &keys[wave * 5_000..(wave + 1) * 5_000];
        c.add_bulk(slice).wait().unwrap();
        for prev in 0..=wave {
            let check = &keys[prev * 5_000..prev * 5_000 + 500];
            assert!(c.query_bulk(check).wait().unwrap().iter().all(|&h| h), "wave {wave} prev {prev}");
        }
    }
    let m = service.stats("waves").unwrap().metrics;
    assert_eq!(m.adds, 20_000);
    assert!(m.batches > 0 && m.mean_batch_size >= 1.0);
}

#[test]
fn zipf_hot_key_traffic() {
    let service = native_service(&[("zipf", spec(15, 2, 512, 100))]);
    let c = service.handle("zipf").unwrap();
    let universe = unique_keys(5_000, 2);
    c.add_bulk(&universe).wait().unwrap();
    let mut z = Zipf::new(universe.len() as u64, 1.3, 7);
    let trace = z.trace(&universe, 30_000);
    let hits = c.query_bulk(&trace).wait().unwrap();
    assert!(hits.iter().all(|&h| h), "hot keys must always hit");
}

#[test]
fn fpr_preserved_through_sharded_service() {
    // sharding must not inflate FPR beyond the single-filter rate by more
    // than noise (each shard is a smaller filter at the same load factor)
    let service = native_service(&[("fpr", spec(15, 4, 4096, 200))]);
    let c = service.handle("fpr").unwrap();
    let (ins, qry) = disjoint_key_sets(80_000, 40_000, 3);
    c.add_bulk(&ins).wait().unwrap();
    let fp = c.query_bulk(&qry).wait().unwrap().iter().filter(|&&h| h).count();
    let fpr = fp as f64 / qry.len() as f64;
    assert!(fpr < 0.05, "service fpr {fpr}");
}

#[test]
fn heavy_concurrency_stress_on_one_namespace() {
    let service = native_service(&[("stress", spec(15, 4, 2048, 200))]);
    std::thread::scope(|scope| {
        for t in 0..16u64 {
            let handle = service.handle("stress").unwrap();
            scope.spawn(move || {
                let keys = unique_keys(4_000, 50 + t);
                handle.add_bulk(&keys).wait().unwrap();
                let hits = handle.query_bulk(&keys).wait().unwrap();
                assert!(hits.iter().all(|&h| h));
            });
        }
    });
    assert_eq!(service.stats("stress").unwrap().metrics.adds, 64_000);
}

// ---- PJRT namespaces (skip without artifacts) ----

#[test]
fn pjrt_namespace_reports_single_state_placement() {
    let Ok(manifest) = Manifest::load(&default_artifact_dir()) else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let actor = EngineActor::spawn_with_manifest(manifest.clone()).unwrap();
    let client = actor.client();
    let config = FilterConfig::default();
    let service = FilterService::new();
    // ask for 4 shards; the single-state PJRT backend places 1 — visible
    // through stats instead of a stderr warning
    let s = FilterSpec {
        config,
        shards: 4,
        policy: BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(300) },
        ..FilterSpec::default()
    };
    service
        .create_filter_with("pjrt", s, move |_| {
            Ok(Box::new(PjrtBackend::new(client, &manifest, config, "pallas")?) as Box<dyn FilterBackend>)
        })
        .unwrap();
    let stats = service.stats("pjrt").unwrap();
    assert_eq!(stats.backend, "pjrt");
    assert_eq!(stats.requested_shards, 4);
    assert_eq!(stats.num_shards, 1, "single-state placement is introspectable");
    assert!(stats.shards.is_empty(), "no per-shard rows for a single-state backend");
    assert!(stats.report().contains("requested 4"), "{}", stats.report());

    let h = service.handle("pjrt").unwrap();
    let keys = unique_keys(6_000, 5);
    h.add_bulk(&keys).wait().unwrap();
    assert!(h.query_bulk(&keys).wait().unwrap().iter().all(|&h| h));
    let (_, absent) = disjoint_key_sets(1, 6_000, 6);
    let fp = h.query_bulk(&absent).wait().unwrap().iter().filter(|&&h| h).count();
    assert!(fp < 600, "pjrt fpr too high: {fp}/6000");
}

// ---- ticket timeout on a genuinely stalled operation ----

/// A backend whose `bulk_add` blocks on a shared gate — the test double
/// for "the backend is wedged / very slow", so `wait_timeout` is
/// exercised against an operation that genuinely has not completed.
struct GatedBackend {
    cfg: FilterConfig,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl FilterBackend for GatedBackend {
    fn config(&self) -> &FilterConfig {
        &self.cfg
    }

    fn backend_name(&self) -> &'static str {
        "gated"
    }

    fn bulk_add(&self, _keys: &[u64]) -> anyhow::Result<()> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(())
    }

    fn bulk_contains(&self, keys: &[u64]) -> anyhow::Result<gbf::filter::AnswerBits> {
        Ok(gbf::filter::AnswerBits::with_len(keys.len()))
    }

    fn snapshot(&self) -> Vec<u64> {
        Vec::new()
    }
}

#[test]
fn wait_timeout_on_stalled_op_hands_the_ticket_back() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let service = FilterService::new();
    let config = cfg(12);
    let backend_gate = Arc::clone(&gate);
    service
        .create_filter_with("stalled", spec(12, 1, 16, 50), move |_| {
            Ok(Box::new(GatedBackend { cfg: config, gate: backend_gate }) as Box<dyn FilterBackend>)
        })
        .unwrap();
    let h = service.handle("stalled").unwrap();
    let t = h.add_bulk(&[1, 2, 3]);
    // the batch worker is blocked inside the backend: a bounded wait must
    // report the timeout variant and hand the ticket back un-consumed
    let t = match t.wait_timeout(Duration::from_millis(50)) {
        Err(ticket) => ticket,
        Ok(r) => panic!("stalled op must time out, got {r:?}"),
    };
    assert!(!t.is_ready(), "still in flight after a timed-out wait");
    // a second bounded wait times out the same way — nothing was consumed
    let t = match t.wait_timeout(Duration::from_millis(10)) {
        Err(ticket) => ticket,
        Ok(r) => panic!("still stalled, got {r:?}"),
    };
    // open the gate: the SAME ticket now resolves through a plain wait
    {
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
    t.wait().unwrap();
}

// ---- transport equivalence: one test body, two transports ----

#[test]
fn transport_equivalence_in_process_vs_wire() {
    // transport 1: the in-process catalog
    let local = FilterService::new();
    let (local_hits, local_stats) = drive_api(&local);

    // transport 2: the same body across a loopback socket
    let remote_service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&remote_service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    let (remote_hits, remote_stats) = drive_api(&client);

    // identical query answers — down to the false positives
    assert_eq!(local_hits, remote_hits, "bit-identical answers across transports");
    // identical accounting, including per-shard counters over the wire
    assert_eq!(local_stats.metrics.adds, remote_stats.metrics.adds);
    assert_eq!(local_stats.metrics.queries, remote_stats.metrics.queries);
    assert_eq!(local_stats.num_shards, remote_stats.num_shards);
    assert_eq!(
        local_stats.shards.iter().map(|s| s.keys).sum::<u64>(),
        remote_stats.shards.iter().map(|s| s.keys).sum::<u64>(),
        "per-shard key totals agree over the wire"
    );
    assert_eq!(local_stats.backend, remote_stats.backend);
}

// ---- `gbf client`-shaped smoke: the full remote lifecycle on a socket ----

#[test]
fn remote_lifecycle_matches_in_process_oracle() {
    // in-process oracle fed exactly the same keys
    let oracle = FilterService::new();
    let oh = oracle.create_filter("smoke", cfg(13), 2).unwrap();
    let keys = unique_keys(4_000, 0x51);
    let (_, absent) = disjoint_key_sets(1, 8_000, 0x52);
    oh.add_bulk(&keys).wait().unwrap();
    let oracle_present = oh.query_bulk(&keys).wait().unwrap();
    let oracle_absent = oh.query_bulk(&absent).wait().unwrap();

    // remote twin: create -> add_bulk -> query_bulk -> stats -> drop
    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    let rh = client.create_filter("smoke", cfg(13), 2).unwrap();
    rh.add_bulk(&keys).wait().unwrap();
    // two queries pipelined on one connection (distinct request ids)
    let t_present = rh.query_bulk(&keys);
    let t_absent = rh.query_bulk(&absent);
    let remote_present = t_present.wait().unwrap();
    let remote_absent = t_absent.wait().unwrap();
    assert!(remote_present.iter().all(|&h| h), "no false negatives over the wire");
    assert_eq!(oracle_present, remote_present);
    assert_eq!(oracle_absent, remote_absent, "identical answers, including false positives");

    let stats = client.stats("smoke").unwrap();
    assert_eq!(stats.backend, "native");
    assert_eq!(stats.num_shards, 2);
    assert_eq!(stats.metrics.adds, 4_000);
    assert_eq!(stats.metrics.queries, 12_000);
    assert_eq!(stats.shards.iter().map(|s| s.keys).sum::<u64>(), 16_000);

    client.drop_filter("smoke").unwrap();
    assert!(client.list_filters().unwrap().is_empty());
    assert!(service.list_filters().is_empty(), "the server-side catalog agrees");
    match client.stats("smoke") {
        Err(GbfError::NoSuchFilter(n)) => assert_eq!(n, "smoke"),
        other => panic!("expected NoSuchFilter, got {other:?}"),
    }

    // a clone of the client shares the connection and still works
    let clone = client.clone();
    clone.create_filter("smoke2", cfg(12), 1).unwrap();
    assert_eq!(client.list_filters().unwrap(), vec!["smoke2".to_string()]);
}

#[test]
fn remote_client_survives_server_shutdown_with_typed_errors() {
    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    client.create_filter("doomed", cfg(12), 1).unwrap();
    drop(server);
    // the dead connection surfaces as a typed Backend error, not a hang
    let mut saw_error = false;
    for _ in 0..50 {
        match client.list_filters() {
            Err(GbfError::Backend(_)) => {
                saw_error = true;
                break;
            }
            Ok(_) => std::thread::sleep(Duration::from_millis(10)),
            Err(other) => panic!("expected Backend error, got {other:?}"),
        }
    }
    assert!(saw_error, "calls after server shutdown fail with GbfError::Backend");
}

#[test]
fn variant_diversity_across_namespaces() {
    // independent configs really are independent: different variants and
    // geometries live side by side in one catalog
    let service = FilterService::new();
    let entries = [
        ("sbf", FilterConfig { variant: Variant::Sbf, log2_m_words: 13, ..Default::default() }),
        ("cbf", FilterConfig { variant: Variant::Cbf, log2_m_words: 12, ..Default::default() }),
        ("bbf", FilterConfig { variant: Variant::Bbf, log2_m_words: 14, ..Default::default() }),
    ];
    for (name, config) in &entries {
        service.create_filter(name, *config, 2).unwrap();
    }
    let keys = unique_keys(3_000, 9);
    let handles: Vec<_> = entries.iter().map(|(n, _)| service.handle(n).unwrap()).collect();
    let tickets: Vec<_> = handles.iter().map(|h| h.add_bulk(&keys)).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    for h in &handles {
        assert!(h.query_bulk(&keys).wait().unwrap().iter().all(|&hit| hit), "{}", h.name());
    }
    assert_eq!(service.list_filters().len(), 3);
}
