//! Chaos suite (ISSUE 10): adversarial fault plans against the
//! wire/persist/cluster stack, driven through the deterministic
//! failpoint registry (`infra::fault`). The whole file compiles away
//! unless the build carries `--cfg failpoints` (CI's chaos job sets
//! `RUSTFLAGS=--cfg failpoints`); the tier-1 build sees an empty suite.
//!
//! The invariants under fire, in every scenario:
//!   - failures surface as TYPED errors (never a wedged ticket, never a
//!     lost wakeup) within a generous wedge bound;
//!   - a write that was ACKED is never lost, no matter what the plan
//!     injected around it;
//!   - pure-delay plans are answer-preserving — timing faults shift
//!     latency, never results;
//!   - once the plan drains (`once`/`xN` budgets spent, or disarm), the
//!     stack recovers without a restart.
//!
//! The registry is process-global, so every test serializes on one gate
//! and re-arms its own plan; `arm` zeroes the hit counters, which makes
//! the per-test `evals`/`fires` assertions exact.
#![cfg(failpoints)]

use std::net::TcpListener;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use gbf::coordinator::{
    ClusterConfig, ClusterFilterService, FilterService, GbfError, RemoteFilterService, RetryPolicy,
    WireServer,
};
use gbf::infra::fault;
use gbf::workload::keygen::unique_keys;

mod common;
use common::{drive_api, scratch_dir, spec};

/// One gate for the process-global registry. A failed test leaves the
/// mutex poisoned; the next test claims the guard anyway (the registry
/// itself is re-armed fresh, so there is no state worth protecting) and
/// disarms whatever the casualty left behind.
static REGISTRY_GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    let g = REGISTRY_GATE.lock().unwrap_or_else(|p| p.into_inner());
    fault::disarm();
    g
}

/// Arms a plan on construction, disarms on drop — so a panicking
/// assertion cannot leak an armed plan into the next test.
struct Armed;

impl Armed {
    fn plan(plan: &str, seed: u64) -> Armed {
        fault::arm(plan, seed).expect("chaos plan parses");
        Armed
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        fault::disarm();
    }
}

/// Generous bound separating "slow under injected delays" from "wedged":
/// no ticket in this suite may take longer than this to resolve.
const WEDGE: Duration = Duration::from_secs(30);

// ---- answer preservation: delays are invisible to correctness ----

/// The UNMODIFIED acceptance driver runs over a loopback wire transport
/// while a pure-delay plan fires on the server's data replies and in the
/// batcher's drain loop. Answers, typed errors, and counters must be
/// bit-identical to the quiet in-process run — delays shift timing and
/// nothing else.
#[test]
fn pure_delay_plan_is_answer_preserving() {
    let _gate = gate();

    // oracle first, with the registry quiet
    let local = FilterService::new();
    let (local_hits, local_stats) = drive_api(&local);

    let _armed = Armed::plan(
        "wire.server.data_reply=delay(2ms):0.2;batcher.drain=delay(1ms):0.2",
        0xFA117,
    );
    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    let (wire_hits, wire_stats) = drive_api(&client);

    assert_eq!(local_hits, wire_hits, "delays shifted an answer");
    assert_eq!(local_stats.metrics.adds, wire_stats.metrics.adds);
    assert_eq!(local_stats.metrics.queries, wire_stats.metrics.queries);
    // the instrumented points were actually on the path (fires are
    // probabilistic; evals are not)
    assert!(fault::evals("wire.server.data_reply") > 0, "data replies never reached the failpoint");
    assert!(fault::evals("batcher.drain") > 0, "the batcher never reached the failpoint");
}

// ---- adversarial plan: typed errors, no wedges, no lost acked writes ----

/// Twenty rounds of writes and reads through a loopback wire transport
/// while a hostile plan fires across the client send path, the server
/// reply path, the persist layer, and the batcher. Every round resolves
/// within the wedge bound — as an ack or a typed error, never a hang —
/// acked keys stay queryable mid-chaos, and after the plan is disarmed
/// the same handle recovers with zero acked writes lost.
#[test]
fn adversarial_plan_yields_typed_errors_and_no_lost_acked_writes() {
    let _gate = gate();

    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    // short per-op deadline: a suppressed reply (`wire.server.pre_reply=err`
    // swallows the frame) costs 500ms of waiting, not the default 10s
    let policy = RetryPolicy { op_timeout: Duration::from_millis(500), ..RetryPolicy::default() };
    let client = RemoteFilterService::connect_lazy_with(server.local_addr(), policy).unwrap();
    let h = client.create_filter_spec("chaos", spec(16, 2, 1024, 150)).unwrap();

    // the once-rule guarantees at least one typed failure
    // deterministically; the probabilistic rules supply the weather
    let armed = Armed::plan(
        "wire.client.send=err:once;wire.server.pre_reply=err:0.1;\
         persist.shard_write=err:0.5;batcher.execute=err:0.05",
        0xD15EA5E,
    );

    let mut acked: Vec<u64> = Vec::new();
    let mut typed_failures = 0u32;
    for round in 0..20u64 {
        let keys = unique_keys(256, 0x1000 + round);
        match h.add_bulk(&keys).wait_timeout(WEDGE) {
            Ok(Ok(())) => acked.extend_from_slice(&keys),
            Ok(Err(_typed)) => typed_failures += 1,
            Err(_ticket) => panic!("wedged add ticket in round {round}"),
        }
        if !acked.is_empty() {
            match h.query_bulk(&acked).wait_timeout(WEDGE) {
                Ok(Ok(hits)) => {
                    assert!(hits.iter().all(|&x| x), "acked key missing mid-chaos (round {round})")
                }
                Ok(Err(_typed)) => typed_failures += 1,
                Err(_ticket) => panic!("wedged query ticket in round {round}"),
            }
        }
        // every fifth round, poke the admin plane: the persist rules make
        // snapshot fail often, but it must fail TYPED and return
        if round % 5 == 4 {
            let dir = scratch_dir("chaos-snap");
            let _ = client.snapshot("chaos", &dir.to_string_lossy());
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    assert!(typed_failures > 0, "the plan never fired — this run proved nothing");

    // plan drained: the SAME handle recovers without a reconnect ritual,
    // and every key that was ever acked is still present
    drop(armed);
    let tail = unique_keys(512, 0x2000);
    h.add_bulk(&tail).wait().unwrap();
    acked.extend_from_slice(&tail);
    let hits = h.query_bulk(&acked).wait().unwrap();
    assert!(hits.iter().all(|&x| x), "an acked write was lost across the chaos window");
    assert_eq!(client.list_filters().unwrap(), vec!["chaos".to_string()]);
}

// ---- determinism: a once-rule fires exactly once, tagged with the op ----

/// `err:once` on the client send path: the FIRST add after arming fails
/// with a typed `Backend` error carrying the failing op name and attempt
/// count (`[op add_bulk, attempt 1/1]` — writes get exactly one
/// shipment), the rule is spent, and the identical retry succeeds.
#[test]
fn once_rule_fires_exactly_once_and_tags_the_failing_op() {
    let _gate = gate();

    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let client = RemoteFilterService::connect(server.local_addr()).unwrap();
    let h = client.create_filter_spec("once", spec(12, 1, 256, 100)).unwrap();

    let _armed = Armed::plan("wire.client.send=err:once", 1);
    let err = h.add_bulk(&[1, 2, 3]).wait().unwrap_err();
    assert!(matches!(err, GbfError::Backend(_)), "injected fault surfaces typed, got {err:?}");
    let msg = err.to_string();
    assert!(msg.contains("[op add_bulk, attempt 1/1]"), "op and attempt count in: {msg}");
    assert_eq!(fault::fires("wire.client.send"), 1);
    assert_eq!(fault::active_rules(), 0, "the once-rule is spent");

    // the spent rule is inert: the same call now succeeds, and the
    // failed shipment provably never reached the backend
    h.add_bulk(&[1, 2, 3]).wait().unwrap();
    assert!(h.query_bulk(&[1, 2, 3]).wait().unwrap().iter().all(|&x| x));
    assert_eq!(fault::fires("wire.client.send"), 1, "no further fires after the budget drained");
    assert_eq!(service.stats("once").unwrap().metrics.adds, 3, "only the acked shipment landed");
}

// ---- persist: a torn shard write never publishes a snapshot ----

/// `torn:once` on the shard writer: the snapshot fails with a typed
/// `SnapshotCorrupt`, the destination directory is never published (a
/// restore from it fails typed too), and with the rule spent the same
/// snapshot succeeds and round-trips bit-identically.
#[test]
fn torn_shard_write_fails_typed_and_never_publishes() {
    let _gate = gate();

    let service = FilterService::new();
    let h = service.create_filter_spec("torn", spec(13, 2, 1024, 150)).unwrap();
    let keys = unique_keys(2_000, 0xD7);
    h.add_bulk(&keys).wait().unwrap();
    let mut probe = keys.clone();
    probe.extend(unique_keys(1_000, 0xD8));
    let pre = h.query_bulk(&probe).wait().unwrap();

    let torn_dir = scratch_dir("chaos-torn");
    let armed = Armed::plan("persist.shard_write=torn:once", 0x70A2);
    match service.snapshot("torn", &torn_dir) {
        Err(GbfError::SnapshotCorrupt(msg)) => {
            assert!(msg.contains("torn shard write"), "torn write names itself: {msg}")
        }
        other => panic!("expected SnapshotCorrupt from the torn shard write, got {other:?}"),
    }
    // nothing was published: the wreckage stays in the temp dir, the
    // destination has no manifest to restore from
    match service.restore("torn-ghost", &torn_dir) {
        Err(GbfError::SnapshotCorrupt(_)) => {}
        other => panic!("a half-written snapshot must not restore, got {other:?}"),
    }
    assert_eq!(fault::fires("persist.shard_write"), 1);
    assert_eq!(fault::active_rules(), 0, "the once-rule is spent");
    drop(armed);

    // rule drained: the same namespace snapshots cleanly and the warm
    // start answers identically — including the false positives
    let good_dir = scratch_dir("chaos-torn-good");
    service.snapshot("torn", &good_dir).unwrap();
    let warm = service.restore("torn-restored", &good_dir).unwrap();
    let post = warm.query_bulk(&probe).wait().unwrap();
    assert_eq!(pre, post, "recovered snapshot answers identically");
    std::fs::remove_dir_all(&torn_dir).ok();
    std::fs::remove_dir_all(&good_dir).ok();
}

// ---- batcher: an injected panic is contained, the worker survives ----

/// `panic:once` inside the batch executor: the panic is caught by the
/// worker's panic shield, the batch fails with a typed `Backend` error
/// naming the panic, and the SAME worker keeps serving — the exact
/// survival path a real panicking backend takes.
#[test]
fn injected_batch_panic_is_contained_and_the_worker_survives() {
    let _gate = gate();

    let service = FilterService::new();
    let h = service.create_filter_spec("boom", spec(12, 1, 256, 100)).unwrap();

    let _armed = Armed::plan("batcher.execute=panic:once", 3);
    let err = h.add_bulk(&[1, 2, 3]).wait().unwrap_err();
    assert!(matches!(err, GbfError::Backend(_)), "panic surfaces typed, got {err:?}");
    assert!(err.to_string().contains("panicked during batch"), "{err}");
    assert_eq!(fault::fires("batcher.execute"), 1);

    // the namespace's one worker survived the panic: same handle, same
    // worker thread, next batch lands (throughput metrics count both
    // batches — they record attempts, success or not)
    h.add_bulk(&[4, 5, 6]).wait().unwrap();
    assert!(h.query_bulk(&[4, 5, 6]).wait().unwrap().iter().all(|&x| x));
    assert_eq!(service.stats("boom").unwrap().metrics.adds, 6);
}

// ---- cluster: reconciliation converges once reseed faults drain ----

/// A dark replica rejoins empty while the first three reseed attempts
/// are injected away (`err:x3`) and the janitor's heal passes run under
/// random delays. Reseeding is idempotent per pass, so the janitor
/// simply retries: once the x3 budget is spent the replica converges to
/// every acked key, with no operator intervention.
#[test]
fn cluster_reconciles_after_reseed_faults_drain() {
    let _gate = gate();

    // reserve an address for the replica that starts dark
    let reserved = TcpListener::bind("127.0.0.1:0").unwrap();
    let dark_addr = reserved.local_addr().unwrap().to_string();
    drop(reserved);

    let live = Arc::new(FilterService::new());
    let server0 = WireServer::bind(Arc::clone(&live), "127.0.0.1:0").unwrap();
    let addrs = vec![server0.local_addr().to_string(), dark_addr.clone()];
    let sync_dir = scratch_dir("chaos-reseed");
    let mut config = ClusterConfig::new(addrs, 2).unwrap();
    config.sync_dir = sync_dir.to_str().unwrap().to_string();
    let cluster = ClusterFilterService::connect(config).unwrap();

    let h = cluster.create_filter_spec("mend", spec(13, 2, 1024, 150)).unwrap();
    let keys = unique_keys(3_000, 0xE8);
    h.add_bulk(&keys).wait().unwrap();

    let armed = Armed::plan("cluster.reseed=err:x3;cluster.janitor.heal=delay(2ms):0.5", 0xC1A05);
    let rejoined = Arc::new(FilterService::new());
    let _server1 = WireServer::bind(Arc::clone(&rejoined), dark_addr.as_str()).unwrap();

    let mut passes = 0u32;
    while rejoined.stats("mend").map(|s| s.metrics.adds).unwrap_or(0) < keys.len() as u64 {
        cluster.reconcile_now();
        passes += 1;
        assert!(passes < 50, "reseed never converged after the x3 budget drained");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        fault::fires("cluster.reseed") >= 3,
        "convergence without consuming the x3 budget — the failpoint is off the reseed path"
    );
    drop(armed);

    let hits = rejoined.handle("mend").unwrap().query_bulk(&keys).wait().unwrap();
    assert!(hits.iter().all(|&x| x), "reseeded replica is missing an acked key");
    std::fs::remove_dir_all(&sync_dir).ok();
}
