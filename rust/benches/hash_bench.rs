//! Hash-pipeline benchmarks: xxHash64, pattern generation per variant.
//! (custom harness — criterion is unavailable offline; same methodology:
//! warmup + CV-converged repetition, see infra::bench)

use gbf::filter::params::{FilterConfig, Scheme, Variant};
use gbf::hash::pattern::{BlockMask, ProbePlan, ProbeSet};
use gbf::hash::{base_hash, xxh64_u64};
use gbf::infra::bench::{black_box, BenchGroup};
use gbf::workload::keygen::unique_keys;

const N: usize = 1 << 20;

fn main() {
    let keys = unique_keys(N, 1);
    let mut group = BenchGroup::new("hash pipeline");

    group.bench("xxh64_u64 x 1M", Some(N as u64), || {
        let mut acc = 0u64;
        for &k in &keys {
            acc ^= xxh64_u64(k, 0);
        }
        black_box(acc);
    });

    group.bench("base_hash x 1M", Some(N as u64), || {
        let mut acc = 0u64;
        for &k in &keys {
            acc ^= base_hash(k);
        }
        black_box(acc);
    });

    // pattern generation per variant (the §4.2 hot loop)
    let configs = [
        ("sbf B=256", FilterConfig { variant: Variant::Sbf, block_bits: 256, k: 16, log2_m_words: 20, ..Default::default() }),
        ("sbf B=1024", FilterConfig { variant: Variant::Sbf, block_bits: 1024, k: 16, log2_m_words: 20, ..Default::default() }),
        ("rbbf B=64", FilterConfig { variant: Variant::Rbbf, block_bits: 64, k: 16, log2_m_words: 20, ..Default::default() }),
        ("csbf B=512 z=2", FilterConfig { variant: Variant::Csbf, block_bits: 512, k: 16, z: 2, log2_m_words: 20, ..Default::default() }),
        ("bbf mult B=256", FilterConfig { variant: Variant::Bbf, block_bits: 256, k: 16, log2_m_words: 20, ..Default::default() }),
        ("bbf iter B=256 (WC)", FilterConfig { variant: Variant::Bbf, block_bits: 256, k: 16, scheme: Scheme::Iter, log2_m_words: 20, ..Default::default() }),
        ("cbf", FilterConfig { variant: Variant::Cbf, k: 16, log2_m_words: 20, ..Default::default() }),
    ];
    for (name, cfg) in configs {
        let plan = ProbePlan::new(&cfg.validate().unwrap());
        let mut probes = ProbeSet::default();
        group.bench(&format!("gen_probes {name}"), Some(N as u64), || {
            for &k in &keys {
                plan.gen_probes(k, &mut probes);
                black_box(probes.masks[0]);
            }
        });
    }

    // block-mask form (the insert path shape)
    let cfg = FilterConfig { variant: Variant::Sbf, block_bits: 256, k: 16, log2_m_words: 20, ..Default::default() };
    let plan = ProbePlan::new(&cfg.validate().unwrap());
    let mut bm = BlockMask::default();
    group.bench("gen_block_mask sbf B=256", Some(N as u64), || {
        for &k in &keys {
            plan.gen_block_mask(k, &mut bm);
            black_box(bm.masks[0]);
        }
    });
}
