//! Paper-table regeneration bench: runs the full experiment harness (one
//! bench per table and figure, per deliverable (d)) and times the PJRT
//! artifact execution path when artifacts are present.

use gbf::experiments;
use gbf::filter::params::FilterConfig;
use gbf::infra::bench::{black_box, BenchGroup};
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::unique_keys;

fn main() {
    // every table & figure of the paper's evaluation
    for exp in ["table1", "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "gups", "cpu", "calibration"] {
        let t0 = std::time::Instant::now();
        experiments::run(exp, Some(std::path::Path::new("results"))).expect(exp);
        println!("[{exp}] regenerated in {:?}", t0.elapsed());
    }

    // PJRT artifact execution throughput (the request-path numbers)
    let Ok(manifest) = Manifest::load(&default_artifact_dir()) else {
        println!("no artifacts: skipping PJRT bench (run `make artifacts`)");
        return;
    };
    let actor = EngineActor::spawn_with_manifest(manifest.clone()).expect("engine");
    let client = actor.client();
    let cfg = FilterConfig::default();
    let mut group = BenchGroup::new("PJRT artifact execution (headline sbf_B256)");
    for batch in manifest.batch_sizes(&cfg, "contains", "pallas") {
        let contains = manifest.find(&cfg, "contains", batch, "pallas").unwrap().name.clone();
        let add = manifest.find(&cfg, "add", batch, "pallas").unwrap().name.clone();
        let keys = unique_keys(batch, 5);
        let state = client.create_state(cfg).unwrap();
        client.add(&add, state, keys.clone(), batch).unwrap();
        group.bench(&format!("contains n={batch}"), Some(batch as u64), || {
            black_box(client.contains(&contains, state, keys.clone()).unwrap());
        });
        group.bench(&format!("add n={batch}"), Some(batch as u64), || {
            client.add(&add, state, keys.clone(), batch).unwrap();
        });
    }
    // jnp-impl ablation twin (L2 vs L1 artifact)
    if let Some(spec) = manifest.find(&cfg, "contains", 4096, "jnp") {
        let keys = unique_keys(4096, 6);
        let words = vec![0u64; cfg.m_words() as usize];
        let name = spec.name.clone();
        group.bench("contains n=4096 (jnp ablation)", Some(4096), || {
            black_box(client.contains_words(&name, words.clone(), keys.clone()).unwrap());
        });
    }
}
