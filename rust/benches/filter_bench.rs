//! Native filter benchmarks: bulk add/contains per variant, thread
//! scaling, the specialized headline hot path, and the coalescer model.

use gbf::filter::params::{FilterConfig, Variant};
use gbf::filter::sbf::bulk_contains_b256_k16;
use gbf::filter::Bloom;
use gbf::gpu_sim::coalescer::{add_trace, Coalescer};
use gbf::infra::bench::{black_box, BenchGroup};
use gbf::workload::keygen::unique_keys;

const N: usize = 1 << 20;

fn cfg(variant: Variant, block_bits: u32, z: u32) -> FilterConfig {
    FilterConfig { variant, block_bits, k: 16, z, log2_m_words: 21, ..Default::default() }
}

fn main() {
    let keys = unique_keys(N, 2);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut group = BenchGroup::new("native filter bulk ops (16 MiB filter)");
    for (name, c) in [
        ("sbf B=256", cfg(Variant::Sbf, 256, 1)),
        ("sbf B=1024", cfg(Variant::Sbf, 1024, 1)),
        ("rbbf B=64", cfg(Variant::Rbbf, 64, 1)),
        ("csbf B=512 z=2", cfg(Variant::Csbf, 512, 2)),
        ("bbf B=256", cfg(Variant::Bbf, 256, 1)),
        ("cbf", cfg(Variant::Cbf, 256, 1)),
    ] {
        let filter = Bloom::<u64>::new(c.validate().unwrap()).unwrap();
        group.bench(&format!("bulk_add {name} ({threads}T)"), Some(N as u64), || {
            filter.bulk_add(&keys, threads);
        });
        group.bench(&format!("bulk_contains {name} ({threads}T)"), Some(N as u64), || {
            black_box(filter.bulk_contains(&keys, threads));
        });
    }

    let mut scaling = BenchGroup::new("thread scaling (sbf B=256)");
    let filter = Bloom::<u64>::new(cfg(Variant::Sbf, 256, 1)).unwrap();
    filter.bulk_add(&keys, threads);
    for t in [1usize, 2, 4, threads] {
        scaling.bench(&format!("bulk_contains {t}T"), Some(N as u64), || {
            black_box(filter.bulk_contains(&keys, t));
        });
    }

    let mut special = BenchGroup::new("specialized hot path (B=256 k=16 lookup)");
    let snapshot = filter.snapshot();
    let mut out = Vec::new();
    special.bench("generic engine 1T", Some(N as u64), || {
        black_box(filter.bulk_contains(&keys, 1));
    });
    special.bench("bulk_contains_b256_k16 1T", Some(N as u64), || {
        bulk_contains_b256_k16(&snapshot, &keys, &mut out);
        black_box(out.len());
    });

    // coalescer ablation: why Θ = s wins for construction (§5.2)
    let mut coal = BenchGroup::new("coalescer trace model (B=1024 add)");
    let c1024 = cfg(Variant::Sbf, 1024, 1).validate().unwrap();
    let trace_keys = unique_keys(32 * 256, 3);
    for (theta, phi) in [(1u32, 1u32), (4, 1), (16, 1)] {
        let trace = add_trace(&c1024, theta, phi, &trace_keys);
        let stats = Coalescer::default().run(&trace);
        println!(
            "  layout Θ={theta:<2} Φ={phi}: {} accesses -> {} transactions (merge x{:.2})",
            stats.accesses,
            stats.transactions,
            stats.merge_factor()
        );
        coal.bench(&format!("trace+simulate Θ={theta}"), Some(trace_keys.len() as u64), || {
            let trace = add_trace(&c1024, theta, phi, &trace_keys);
            black_box(Coalescer::default().run(&trace));
        });
    }
}
