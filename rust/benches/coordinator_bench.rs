//! Coordinator benchmarks: the sharded registry's parallel bulk path,
//! router, and end-to-end **FilterService** throughput — single namespace
//! vs. many namespaces under the same total load (tenant isolation is the
//! L3 story: per-namespace batchers must not serialize cross-tenant
//! traffic).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use gbf::coordinator::{
    BatchPolicy, FilterService, FilterSpec, RemoteFilterService, Router, ShardedRegistry, WireServer,
};
use gbf::filter::params::FilterConfig;
use gbf::infra::bench::{black_box, BenchGroup};
use gbf::workload::keygen::unique_keys;

fn service_with(namespaces: &[&str], shards: usize, policy: &BatchPolicy) -> FilterService {
    let service = FilterService::new();
    for name in namespaces {
        let spec = FilterSpec {
            config: FilterConfig { log2_m_words: 18, ..Default::default() },
            shards,
            policy: policy.clone(),
            ..FilterSpec::default()
        };
        service.create_filter_spec(name, spec).unwrap();
    }
    service
}

fn main() {
    let keys = unique_keys(1 << 16, 4);

    let mut router = BenchGroup::new("router");
    let r = Router::new(8);
    router.bench("shard_of x 65k", Some(keys.len() as u64), || {
        let mut acc = 0usize;
        for &k in &keys {
            acc += r.shard_of(k);
        }
        black_box(acc);
    });
    router.bench("partition x 65k", Some(keys.len() as u64), || {
        black_box(r.partition(&keys));
    });

    // the sharded registry itself: per-shard-count bulk throughput
    // (split -> parallel threadpool execution -> request-order reassembly)
    let mut registry = BenchGroup::new("sharded registry bulk ops (2 MiB/shard)");
    for shards in [1usize, 2, 4, 8] {
        let reg = ShardedRegistry::new(
            FilterConfig { log2_m_words: 18, ..Default::default() },
            shards,
        )
        .unwrap();
        registry.bench(&format!("bulk_add {shards} shard(s)"), Some(keys.len() as u64), || {
            reg.bulk_add(&keys).unwrap();
        });
        registry.bench(&format!("bulk_contains {shards} shard(s)"), Some(keys.len() as u64), || {
            black_box(reg.bulk_contains(&keys).unwrap());
        });
    }

    let policy = BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(200) };

    // single namespace, 4 concurrent clients — the pre-redesign shape
    let mut single = BenchGroup::new("service: 1 namespace x 4 clients (4 shards)");
    {
        let service = Arc::new(service_with(&["solo"], 4, &policy));
        let handle = service.handle("solo").unwrap();
        handle.add_bulk(&keys).wait().unwrap();
        let bench_keys = keys.clone();
        single.bench("query 65k split across clients", Some(keys.len() as u64), move || {
            std::thread::scope(|scope| {
                for chunk in bench_keys.chunks(bench_keys.len() / 4) {
                    let handle = handle.clone();
                    scope.spawn(move || {
                        black_box(handle.query_bulk(chunk).wait().unwrap());
                    });
                }
            });
        });
    }

    // same total load spread over 4 namespaces, one client each: isolated
    // batchers + state should match or beat the single shared namespace
    let mut multi = BenchGroup::new("service: 4 namespaces x 1 client (1 shard each)");
    {
        let names = ["t0", "t1", "t2", "t3"];
        let service = Arc::new(service_with(&names, 1, &policy));
        for name in names {
            service.handle(name).unwrap().add_bulk(&keys).wait().unwrap();
        }
        let handles: Vec<_> = names.iter().map(|n| service.handle(n).unwrap()).collect();
        let bench_keys = keys.clone();
        multi.bench("query 65k split across tenants", Some(keys.len() as u64), move || {
            std::thread::scope(|scope| {
                for (handle, chunk) in handles.iter().zip(bench_keys.chunks(bench_keys.len() / 4)) {
                    let handle = handle.clone();
                    scope.spawn(move || {
                        black_box(handle.query_bulk(chunk).wait().unwrap());
                    });
                }
            });
        });
    }

    // contention: a hot tenant continuously streaming bulk queries in the
    // background while the timed region covers ONLY the latency tenant's
    // single-key lookups — per-namespace isolation means the hot queue
    // must not slow the latency tenant's path
    let mut contention = BenchGroup::new("service: hot tenant + latency tenant");
    {
        let service = Arc::new(service_with(&["hot", "latency"], 2, &policy));
        let hot = service.handle("hot").unwrap();
        let lat = service.handle("latency").unwrap();
        hot.add_bulk(&keys).wait().unwrap();
        lat.add_bulk(&keys[..1024]).wait().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let hot_thread = {
            let stop = Arc::clone(&stop);
            let hot_keys = keys.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    black_box(hot.query_bulk(&hot_keys).wait().unwrap());
                }
            })
        };
        let bench_keys = keys.clone();
        contention.bench("1k single-key lookups under hot bulk load", Some(1024), move || {
            for &k in &bench_keys[..1024] {
                black_box(lat.query(k).wait().unwrap());
            }
        });
        stop.store(true, Ordering::Relaxed);
        hot_thread.join().unwrap();
    }

    // transport overhead: the identical bulk query served by the same
    // namespace in-process vs across a loopback wire connection — the
    // delta is the frame codec + TCP round-trip cost per 65k-key call
    let mut transport = BenchGroup::new("service: in-process vs loopback wire (4 shards)");
    {
        let service = Arc::new(service_with(&["xport"], 4, &policy));
        let handle = service.handle("xport").unwrap();
        handle.add_bulk(&keys).wait().unwrap();
        let local_handle = handle.clone();
        let local_keys = keys.clone();
        transport.bench("query 65k in-process", Some(keys.len() as u64), move || {
            black_box(local_handle.query_bulk(&local_keys).wait().unwrap());
        });
        let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
        let client = RemoteFilterService::connect(server.local_addr()).unwrap();
        let remote_handle = client.handle("xport").unwrap();
        let remote_keys = keys.clone();
        transport.bench("query 65k loopback wire", Some(keys.len() as u64), move || {
            black_box(remote_handle.query_bulk(&remote_keys).wait().unwrap());
        });
    }
}
