//! Coordinator benchmarks: the sharded registry's parallel bulk path,
//! batcher formation, router, and end-to-end service throughput under
//! different batch policies (the L3 hot path).

use std::sync::Arc;
use std::time::Duration;

use gbf::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, FilterBackend, NativeBackend, Router,
    ShardedRegistry,
};
use gbf::filter::params::FilterConfig;
use gbf::infra::bench::{black_box, BenchGroup};
use gbf::workload::keygen::unique_keys;

fn native(shards: usize, policy: BatchPolicy) -> Coordinator {
    Coordinator::new(CoordinatorConfig { num_shards: shards, policy }, |num_shards| {
        Ok(Box::new(NativeBackend::new(
            FilterConfig { log2_m_words: 18, ..Default::default() },
            num_shards,
        )?) as Box<dyn FilterBackend>)
    })
    .unwrap()
}

fn main() {
    let keys = unique_keys(1 << 16, 4);

    let mut router = BenchGroup::new("router");
    let r = Router::new(8);
    router.bench("shard_of x 65k", Some(keys.len() as u64), || {
        let mut acc = 0usize;
        for &k in &keys {
            acc += r.shard_of(k);
        }
        black_box(acc);
    });
    router.bench("partition x 65k", Some(keys.len() as u64), || {
        black_box(r.partition(&keys));
    });

    // the sharded registry itself: per-shard-count bulk throughput
    // (split -> parallel threadpool execution -> request-order reassembly)
    let mut registry = BenchGroup::new("sharded registry bulk ops (2 MiB/shard)");
    for shards in [1usize, 2, 4, 8] {
        let reg = ShardedRegistry::new(
            FilterConfig { log2_m_words: 18, ..Default::default() },
            shards,
        )
        .unwrap();
        registry.bench(&format!("bulk_add {shards} shard(s)"), Some(keys.len() as u64), || {
            reg.bulk_add(&keys).unwrap();
        });
        registry.bench(&format!("bulk_contains {shards} shard(s)"), Some(keys.len() as u64), || {
            black_box(reg.bulk_contains(&keys).unwrap());
        });
    }

    let mut e2e = BenchGroup::new("coordinator end-to-end (sharded native backend)");
    for (label, max_batch, wait_us) in [
        ("batch 256 / 100µs", 256usize, 100u64),
        ("batch 4096 / 200µs", 4096, 200),
        ("batch 16384 / 500µs", 16384, 500),
    ] {
        let c = Arc::new(native(
            4,
            BatchPolicy { max_batch, max_wait: Duration::from_micros(wait_us) },
        ));
        let coordinator = Arc::clone(&c);
        let bench_keys = keys.clone();
        e2e.bench(&format!("query {label}"), Some(keys.len() as u64), move || {
            // 4 concurrent clients, keys split between them
            std::thread::scope(|scope| {
                for chunk in bench_keys.chunks(bench_keys.len() / 4) {
                    let coordinator = Arc::clone(&coordinator);
                    scope.spawn(move || {
                        black_box(coordinator.query_blocking(chunk).unwrap());
                    });
                }
            });
        });
        println!("    -> {}", c.metrics().report().replace('\n', "\n    -> "));
    }

    let mut shards = BenchGroup::new("end-to-end shard scaling (batch 4096)");
    for s in [1usize, 2, 4, 8] {
        let c = Arc::new(native(s, BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(200) }));
        let coordinator = Arc::clone(&c);
        let bench_keys = keys.clone();
        shards.bench(&format!("query {s} shards"), Some(keys.len() as u64), move || {
            std::thread::scope(|scope| {
                for chunk in bench_keys.chunks(bench_keys.len() / 4) {
                    let coordinator = Arc::clone(&coordinator);
                    scope.spawn(move || {
                        black_box(coordinator.query_blocking(chunk).unwrap());
                    });
                }
            });
        });
    }
}
