"""AOT pipeline: lower every (config, op, batch) to HLO text + manifest.

HLO *text* (not a serialized HloModuleProto) is the interchange format: the
`xla` crate's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids, while the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Also emits artifacts/golden.json - cross-language test vectors that pin the
Rust hash/filter implementations bit-for-bit to the Python reference.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import hashing as H
from .kernels import ref
from .kernels.patterns import gen_probes
from .params import FilterConfig

# ---------------------------------------------------------------- artifacts

# The default artifact set: the paper's headline SBF configuration, the
# RBBF extreme, a CSBF, the WarpCore-style BBF comparator, and a CBF
# baseline. log2_m_words=17 -> 1 MiB filters (shape is baked into the HLO).
DEFAULT_LOG2_M = 17
DEFAULT_BATCHES = (256, 4096)


def default_configs() -> list[FilterConfig]:
    m = DEFAULT_LOG2_M
    return [
        FilterConfig(variant="sbf", block_bits=256, k=16, theta=1, phi=4, log2_m_words=m),
        FilterConfig(variant="rbbf", block_bits=64, k=16, log2_m_words=m),
        FilterConfig(variant="csbf", block_bits=512, k=16, z=2, theta=1, phi=8, log2_m_words=m),
        FilterConfig(variant="bbf", block_bits=256, k=16, scheme="iter", theta=4, phi=1, log2_m_words=m),
        FilterConfig(variant="cbf", k=16, log2_m_words=m),
    ]


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every op here has a single array output, so the
    # ENTRY root is the bare array. This lets the Rust runtime keep the
    # filter as a device-resident PjRtBuffer and feed the add-output buffer
    # straight back as the next call's input (no host round-trip).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_one(cfg: FilterConfig, op: str, batch: int, impl: str) -> str:
    fn = model.build_op(cfg, op, batch, impl=impl)
    lowered = jax.jit(fn).lower(*model.abstract_inputs(cfg, op, batch))
    return to_hlo_text(lowered)


def artifact_name(cfg: FilterConfig, op: str, batch: int, impl: str) -> str:
    suffix = f"_{impl}" if impl != "pallas" else ""
    return f"{cfg.name()}_{op}_n{batch}{suffix}"


def build_artifacts(out_dir: str, configs, batches, with_jnp_ablation: bool = True):
    entries = []
    jobs = [(cfg, op, batch, "pallas") for cfg in configs for op in ("contains", "add") for batch in batches]
    if with_jnp_ablation:
        head = configs[0]
        jobs += [(head, op, max(batches), "jnp") for op in ("contains", "add")]
    for cfg, op, batch, impl in jobs:
        name = artifact_name(cfg, op, batch, impl)
        fname = name + ".hlo.txt"
        t0 = time.time()
        text = lower_one(cfg, op, batch, impl)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "op": op,
            "impl": impl,
            "batch": batch,
            **cfg.to_dict(),
        }
        entries.append(entry)
        print(f"  {name}: {len(text)} chars in {time.time() - t0:.2f}s")
    return entries


# ------------------------------------------------------------------- golden


def _hex(x) -> str:
    return f"{int(x):016x}"


def golden_configs() -> list[FilterConfig]:
    m = 10  # 1024 words - small enough to dump, large enough to exercise blocks
    return [
        FilterConfig(variant="sbf", block_bits=256, k=16, log2_m_words=m),
        FilterConfig(variant="sbf", block_bits=1024, k=16, log2_m_words=m),
        FilterConfig(variant="rbbf", block_bits=64, k=16, log2_m_words=m),
        FilterConfig(variant="csbf", block_bits=512, k=16, z=2, log2_m_words=m),
        FilterConfig(variant="csbf", block_bits=1024, k=16, z=4, log2_m_words=m),
        FilterConfig(variant="bbf", block_bits=256, k=16, log2_m_words=m),
        FilterConfig(variant="bbf", block_bits=256, k=16, scheme="iter", log2_m_words=m),
        FilterConfig(variant="cbf", k=16, log2_m_words=m),
        FilterConfig(variant="sbf", block_bits=128, word_bits=32, k=8, log2_m_words=m),
    ]


def build_golden(out_dir: str, n_keys: int = 64):
    keys = np.array(H._splitmix64_stream(42, n_keys), dtype=np.uint64)
    base = H.xxh64_u64(keys)
    cases = []
    for cfg in golden_configs():
        cfg.validate()
        word_idx, masks = gen_probes(cfg, keys)
        words = ref.new_filter(cfg)
        ref.add_ref(cfg, words, keys[: n_keys // 2])
        hits = ref.contains_ref(cfg, words, keys)
        nz = np.nonzero(words)[0]
        cases.append(
            {
                "config": cfg.to_dict(),
                "probes": [
                    {
                        "key": _hex(keys[i]),
                        "words": [int(w) for w in word_idx[i]],
                        "masks": [_hex(mk) for mk in masks[i]],
                    }
                    for i in range(8)
                ],
                "inserted": n_keys // 2,
                "filter_nonzero": [[int(i), _hex(words[i])] for i in nz],
                "contains": [int(b) for b in hits],
            }
        )
    doc = {
        "seed_base": _hex(H.SEED_BASE),
        "salt_stream_seed": _hex(H.SALT_STREAM_SEED),
        "salts": [_hex(s) for s in H.SALTS],
        "keys": [_hex(k) for k in keys],
        "base_hashes": [_hex(b) for b in base],
        "cases": cases,
    }
    path = os.path.join(out_dir, "golden.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"  golden.json: {len(cases)} cases")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--log2-m-words", type=int, default=DEFAULT_LOG2_M)
    ap.add_argument("--skip-hlo", action="store_true", help="only regenerate golden.json")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    print("golden vectors:")
    build_golden(args.out_dir)

    entries = []
    if not args.skip_hlo:
        print("artifacts:")
        configs = default_configs()
        if args.log2_m_words != DEFAULT_LOG2_M:
            configs = [
                FilterConfig(**{**c.to_dict(), "log2_m_words": args.log2_m_words}) for c in configs
            ]
        entries = build_artifacts(args.out_dir, configs, DEFAULT_BATCHES)

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
