"""L1 kernels: shared fingerprint pipeline, pure-numpy oracle, Pallas kernels."""
