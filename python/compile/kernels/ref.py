"""Pure-numpy oracle for every filter variant.

This is the correctness ground truth for the Pallas kernels (pytest) and for
the Rust native backend (via artifacts/golden.json). Deliberately simple and
sequential-in-spirit: numpy's `bitwise_or.at` handles duplicate indices the
same way atomic OR does.
"""

from __future__ import annotations

import numpy as np

from ..params import FilterConfig
from .patterns import gen_probes


def word_dtype(cfg: FilterConfig):
    return np.uint64 if cfg.word_bits == 64 else np.uint32


def new_filter(cfg: FilterConfig) -> np.ndarray:
    return np.zeros(cfg.m_words, dtype=word_dtype(cfg))


def add_ref(cfg: FilterConfig, words: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Insert keys; returns the updated filter (in place on `words`)."""
    keys = np.asarray(keys, dtype=np.uint64)
    word_idx, masks = gen_probes(cfg, keys)
    np.bitwise_or.at(words, word_idx.ravel(), masks.ravel().astype(words.dtype))
    return words


def contains_ref(cfg: FilterConfig, words: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Membership test; returns bool[n]."""
    keys = np.asarray(keys, dtype=np.uint64)
    word_idx, masks = gen_probes(cfg, keys)
    masks = masks.astype(words.dtype)
    got = words[word_idx]
    return ((got & masks) == masks).all(axis=1)


def measure_fpr(cfg: FilterConfig, n_insert: int, n_query: int, seed: int = 7) -> float:
    """Empirical FPR per the paper's §5.1 methodology (scaled down):

    insert n_insert distinct keys, query n_query keys disjoint from them,
    report the false-positive fraction.
    """
    rng = np.random.default_rng(seed)
    # even keys are inserted, odd keys queried -> disjoint by construction
    ins = (rng.choice(np.iinfo(np.int64).max, size=n_insert, replace=False).astype(np.uint64)) << np.uint64(1)
    qry = ((rng.choice(np.iinfo(np.int64).max, size=n_query, replace=False).astype(np.uint64)) << np.uint64(1)) | np.uint64(1)
    words = new_filter(cfg)
    add_ref(cfg, words, ins)
    return float(contains_ref(cfg, words, qry).mean())
