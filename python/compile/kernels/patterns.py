"""Key-pattern generation for every filter variant (paper §2.1 + §4.2).

For a batch of keys this produces the probe set: `P = cfg.words_per_key`
pairs of (word index into the filter array, word-sized bit mask). Insertion
ORs each mask into its word; lookup tests that every mask is fully present.

The representation is uniform across variants:
    cbf   P = k     one single-bit mask anywhere in the filter
    bbf   P = k     one single-bit mask anywhere in the key's block
    rbbf  P = 1     all k bits in the key's single word   (s = 1)
    sbf   P = s     k/s bits in each word of the key's block
    csbf  P = z     k/z bits in one chosen sector per group

Array-library agnostic (numpy or jax.numpy uint64 inputs).
"""

from __future__ import annotations

import numpy as np

from ..params import FilterConfig
from . import hashing as H


def _one(x):
    """uint64 1 compatible with numpy/jnp broadcasting."""
    return np.uint64(1)


def block_index(cfg: FilterConfig, base):
    """Block selector: top log2(num_blocks) bits of base * SALT_BLOCK."""
    return H.tophash(base, H.salt_block(), cfg.log2_num_blocks)


def gen_probes(cfg: FilterConfig, keys):
    """Return (word_idx, masks): two [n, P] arrays (int64 / uint64).

    Masks always fit the word size; callers cast to uint32 when S = 32.
    """
    base = H.xxh64_u64(keys)
    v = cfg.variant
    log2_s_bits = cfg.log2_word_bits

    if v == "cbf":
        words, masks = [], []
        for i in range(cfg.k):
            pos = H.tophash(base, H.salt_bit(i), cfg.log2_m_bits)
            words.append((pos >> np.uint64(log2_s_bits)).astype(np.int64))
            masks.append(_one(base) << (pos & np.uint64(cfg.word_bits - 1)))
        return _stack(words), _stack(masks)

    blk = block_index(cfg, base)
    bw0 = (blk.astype(np.int64)) * np.int64(cfg.s)

    if v in ("sbf", "rbbf"):
        kpw = cfg.k_per_word
        words, masks = [], []
        for w in range(cfg.s):
            m = None
            for j in range(kpw):
                pos = H.tophash(base, H.salt_bit(w * kpw + j), log2_s_bits)
                bit = _one(base) << pos
                m = bit if m is None else (m | bit)
            words.append(bw0 + np.int64(w))
            masks.append(m)
        return _stack(words), _stack(masks)

    if v == "bbf":
        if cfg.scheme == "iter":
            positions = H.iter_chain(base, cfg.k, cfg.log2_block_bits)
        else:
            positions = [
                H.tophash(base, H.salt_bit(i), cfg.log2_block_bits) for i in range(cfg.k)
            ]
        words, masks = [], []
        for pos in positions:
            words.append(bw0 + (pos >> np.uint64(log2_s_bits)).astype(np.int64))
            masks.append(_one(base) << (pos & np.uint64(cfg.word_bits - 1)))
        return _stack(words), _stack(masks)

    if v == "csbf":
        spg, kpg = cfg.sectors_per_group, cfg.k_per_group
        log2_spg = spg.bit_length() - 1
        words, masks = [], []
        for g in range(cfg.z):
            sec = H.tophash(base, H.salt_group(g), log2_spg).astype(np.int64)
            words.append(bw0 + np.int64(g * spg) + sec)
            m = None
            for j in range(kpg):
                pos = H.tophash(base, H.salt_bit(g * kpg + j), log2_s_bits)
                bit = _one(base) << pos
                m = bit if m is None else (m | bit)
            masks.append(m)
        return _stack(words), _stack(masks)

    raise ValueError(v)


def gen_block_masks(cfg: FilterConfig, keys):
    """Blocked variants only: (block_word0[n], mask_vec[n, s]).

    The per-key probe set expanded to a dense s-word block mask - the shape
    insertion kernels want: one contiguous load + OR + store per key
    (the Pallas analogue of issuing all block atomics in one tight window,
    paper §5.2 "temporal coalescing").
    """
    assert cfg.is_blocked
    word_idx, masks = gen_probes(cfg, keys)
    bw0 = (word_idx[:, 0] // cfg.s) * cfg.s  # block start is invariant per key
    local = word_idx - bw0[:, None]  # [n, P] in 0..s-1
    if cfg.variant in ("sbf", "rbbf"):
        return bw0, masks  # already dense: P == s, local == arange(s)
    # Scatter P probes into s slots with OR (duplicates possible for bbf).
    # Built from scalar comparisons only: Pallas kernels may not capture
    # array constants, so no arange/one-hot tables here. The (s x P) compare
    # grid is statically unrolled, mirroring the paper's template unrolling.
    s, P = cfg.s, masks.shape[1]
    cols = []
    for w_slot in range(s):
        acc = None
        for p in range(P):
            hit = (local[:, p] == w_slot).astype(masks.dtype)
            contrib = masks[:, p] * hit
            acc = contrib if acc is None else (acc | contrib)
        cols.append(acc)
    return bw0, _stack(cols)


def _stack(cols):
    """Stack per-probe columns to [n, P]; works for numpy and jnp arrays."""
    if isinstance(cols[0], np.ndarray):
        return np.stack(cols, axis=1)
    import jax.numpy as jnp

    return jnp.stack(cols, axis=1)
