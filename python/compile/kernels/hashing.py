"""Fingerprint pipeline: xxHash64 base hash + branchless multiplicative salts.

Paper §4.2: one strong base hash per key (xxHash64 [6]), then every bit
position / block index / group-sector choice is derived by multiplying the
base hash with a distinct odd 64-bit constant and keeping the *top* bits of
the product (Dietzfelbinger-style universal hashing [9]). This is branchless,
needs exactly one hash evaluation per key, and maps 1:1 onto the inlined-salt
code generation the paper performs with C++ templates.

The module is array-library agnostic: every function works on numpy *and*
jax.numpy uint64 arrays (both wrap modulo 2^64 and keep uint64 under NEP 50
weak promotion), so the same code serves the numpy oracle (ref.py), the JAX
model (model.py) and the Pallas kernels (sbf_kernel.py). The Rust mirror
lives in rust/src/hash/; artifacts/golden.json pins them bit-for-bit.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1

# xxHash64 primes (Collet [6]).
XXH_PRIME64_1 = 0x9E3779B185EBCA87
XXH_PRIME64_2 = 0xC2B2AE3D27D4EB4F
XXH_PRIME64_3 = 0x165667B19E3779F9
XXH_PRIME64_4 = 0x85EBCA77C2B2AE63
XXH_PRIME64_5 = 0x27D4EB2F165667C5

# Base-hash seed (fixed across the whole stack).
SEED_BASE = 0xB10000F117E55EED

# Salt schedule: a splitmix64 stream seeded with the fractional bits of pi,
# forced odd. Salt roles:
#   SALTS[0]          block selection
#   SALTS[1 + g]      CSBF group-g sector selection (g < 16)
#   SALTS[17 + i]     fingerprint bit i (i < 79)
SALT_STREAM_SEED = 0x243F6A8885A308D3
NUM_SALTS = 96


def _splitmix64_stream(seed: int, count: int) -> tuple[int, ...]:
    out, state = [], seed & MASK64
    for _ in range(count):
        state = (state + 0x9E3779B97F4A7C15) & MASK64
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        out.append(z ^ (z >> 31))
    return tuple(out)


SALTS: tuple[int, ...] = tuple(x | 1 for x in _splitmix64_stream(SALT_STREAM_SEED, NUM_SALTS))


def salt_block() -> int:
    return SALTS[0]


def salt_group(g: int) -> int:
    assert 0 <= g < 16
    return SALTS[1 + g]


def salt_bit(i: int) -> int:
    assert 0 <= i < NUM_SALTS - 17
    return SALTS[17 + i]


def _u64(x: int):
    """A uint64 constant usable with both numpy and jnp arrays."""
    return np.uint64(x & MASK64)


def rotl64(x, r: int):
    """Rotate-left on uint64 arrays."""
    return (x << _u64(r)) | (x >> _u64(64 - r))


def xxh64_u64(key, seed: int = SEED_BASE):
    """xxHash64 of a single 8-byte little-endian lane (the u64 key).

    This is the exact XXH64 algorithm specialized to an 8-byte input:
    no stripe accumulators, one mid-loop fold, then the avalanche.
    `key` is a uint64 array (numpy or jnp); returns the same array type.
    """
    # Modular wraparound is the point of every multiply below; keep numpy
    # from warning about it (jnp wraps silently anyway).
    np.seterr(over="ignore")
    h = _u64(seed + XXH_PRIME64_5 + 8)
    k1 = key * _u64(XXH_PRIME64_2)
    k1 = rotl64(k1, 31)
    k1 = k1 * _u64(XXH_PRIME64_1)
    h = h ^ k1
    h = rotl64(h, 27) * _u64(XXH_PRIME64_1) + _u64(XXH_PRIME64_4)
    # avalanche
    h = h ^ (h >> _u64(33))
    h = h * _u64(XXH_PRIME64_2)
    h = h ^ (h >> _u64(29))
    h = h * _u64(XXH_PRIME64_3)
    h = h ^ (h >> _u64(32))
    return h


def tophash(base, salt: int, nbits: int):
    """Universal multiplicative hash: top `nbits` of (base * salt) mod 2^64.

    nbits == 0 yields all-zeros (e.g. block index when there is one block).
    """
    if nbits == 0:
        return base & _u64(0)
    return (base * _u64(salt)) >> _u64(64 - nbits)


def iter_chain(base, length: int, log2_range: int):
    """WarpCore-style iterative re-hash pattern generation (paper §4.2).

    h_0 = base; h_{i+1} = xxh64(h_i ^ (i+1)). Position i is the top
    log2_range bits of h_i. Returns a list of `length` position arrays.
    Sequential by construction - this is the scheme whose serial latency the
    paper's multiplicative hashing removes.
    """
    positions = []
    h = base
    for i in range(length):
        positions.append(h >> _u64(64 - log2_range))
        if i + 1 < length:
            h = xxh64_u64(h ^ _u64(i + 1))
    return positions
