"""L1 Pallas kernels: bulk `contains` and `add` for every filter variant.

The paper's compute hot-spot - fused fingerprint generation + filter probe -
is expressed as Pallas kernels parameterized by the (Θ, Φ) vectorization
design space of §4.1:

  * Φ (vertical): contiguous words consumed per vector step. In the lookup
    kernel the per-key probe axis is reshaped into [steps, Θ, Φ] and reduced
    innermost-first, mirroring `ld.global.vN` wide loads feeding a statically
    unrolled loop.
  * Θ (horizontal): lanes cooperating on one key. The Θ axis of the same
    reshape models the cooperative-group split; the final `all` over Θ is the
    warp-vote.

Every (Θ, Φ) layout computes bit-identical results (property-tested); the
layouts differ in HLO structure, and their *hardware* consequences are
modeled by rust/src/gpu_sim (see DESIGN.md §1).

Insertion performs one contiguous read-modify-write OR per key block inside
a sequential `fori_loop`. Pallas interpret mode executes this determin-
istically; OR's commutativity makes the order irrelevant, which is exactly
why the CUDA original can use relaxed atomics. A scalar `n_valid` input
supports partially-filled batches (the coordinator pads to a fixed shape).

TPU adaptation note (DESIGN.md §Hardware-Adaptation): these kernels carry
the paper's *algorithmic* design space. On a real TPU the block probe maps
to VMEM-tiled gathers rather than L1-sector loads; `interpret=True` is
mandatory here because Mosaic custom-calls cannot execute on the CPU PJRT
plugin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import FilterConfig
from .patterns import gen_block_masks, gen_probes


def word_dtype(cfg: FilterConfig):
    return jnp.uint64 if cfg.word_bits == 64 else jnp.uint32


def _structured_all(ok, cfg: FilterConfig):
    """Reduce the per-probe axis in (steps, Θ, Φ) order (paper Fig. 2)."""
    n, P = ok.shape
    tp = cfg.theta * cfg.phi
    if tp > 1 and P % tp == 0:
        ok = ok.reshape(n, P // tp, cfg.theta, cfg.phi)
        return ok.all(axis=3).all(axis=2).all(axis=1)
    return ok.all(axis=1)


def make_contains(cfg: FilterConfig, batch: int, interpret: bool = True):
    """Bulk lookup kernel: (filter[m_words], keys[batch]) -> hits uint8[batch]."""
    cfg.validate()
    dtype = word_dtype(cfg)
    P = cfg.words_per_key

    def kernel(f_ref, k_ref, o_ref):
        keys = k_ref[...]
        word_idx, masks = gen_probes(cfg, keys)
        masks = masks.astype(dtype)
        got = f_ref[word_idx.reshape(-1)].reshape(batch, P)
        ok = (got & masks) == masks
        o_ref[...] = _structured_all(ok, cfg).astype(jnp.uint8)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.uint8),
        interpret=interpret,
    )


def make_add(cfg: FilterConfig, batch: int, interpret: bool = True):
    """Bulk insert kernel:
    (keys[batch], n_valid[1] i32, filter[m_words]) -> filter'[m_words].

    The filter argument is aliased to the output, so the kernel performs
    in-place OR updates - the functional analogue of `atomicOr` (§2.2).
    """
    cfg.validate()
    dtype = word_dtype(cfg)
    s = cfg.s

    if cfg.is_blocked:

        def kernel(k_ref, n_ref, f_ref, o_ref):
            del f_ref  # aliased into o_ref
            keys = k_ref[...]
            bw0, mvec = gen_block_masks(cfg, keys)
            mvec = mvec.astype(dtype)

            def body(i, carry):
                # One contiguous RMW per key: the tightest possible window
                # for the paper's temporal atomic-coalescing (§5.2).
                blk = o_ref[pl.ds(bw0[i], s)]
                o_ref[pl.ds(bw0[i], s)] = blk | mvec[i]
                return carry

            jax.lax.fori_loop(0, n_ref[0], body, 0)

    else:  # cbf: probes scatter across the whole array

        def kernel(k_ref, n_ref, f_ref, o_ref):
            del f_ref
            keys = k_ref[...]
            word_idx, masks = gen_probes(cfg, keys)
            masks = masks.astype(dtype)

            def body(i, carry):
                for p in range(cfg.k):  # statically unrolled (§4.2)
                    w = o_ref[pl.ds(word_idx[i, p], 1)]
                    o_ref[pl.ds(word_idx[i, p], 1)] = w | masks[i, p]
                return carry

            jax.lax.fori_loop(0, n_ref[0], body, 0)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((cfg.m_words,), dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )
