"""Filter geometry, validation, and the paper's accuracy math (Eq. 1-3).

This module is the single Python source of truth for filter configuration.
`rust/src/filter/params.rs` mirrors it field-for-field; the cross-language
golden tests (artifacts/golden.json) pin the two against each other.

Terminology (paper §2.1-§2.2):
    m_bits      total filter size in bits (power of two here)
    m_words     m_bits / S
    S           word ("sector" in the paper's filter sense) size in bits
    B           block size in bits, one block per key for blocked variants
    s           words per block = B / S
    k           fingerprint bits per key
    z           CSBF: number of sector groups per block
    c           bits per element = m / n
    Θ (theta)   horizontal vectorization: lanes cooperating per key
    Φ (phi)     vertical vectorization: contiguous words per vector load
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

VARIANTS = ("cbf", "bbf", "rbbf", "sbf", "csbf")
SCHEMES = ("mult", "iter")


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def _log2(x: int) -> int:
    assert _is_pow2(x), f"{x} is not a power of two"
    return x.bit_length() - 1


@dataclass(frozen=True)
class FilterConfig:
    """A fully-specified filter configuration.

    The default is the paper's headline configuration: an SBF with
    B = 256-bit blocks of S = 64-bit words and k = 16 fingerprint bits.
    """

    variant: str = "sbf"
    log2_m_words: int = 17  # 2^17 * 8 B = 1 MiB filter
    word_bits: int = 64  # S; the paper keeps S = 64 throughout §5
    block_bits: int = 256  # B
    k: int = 16
    z: int = 1  # CSBF group count (ignored otherwise)
    scheme: str = "mult"  # "iter" = WarpCore-style sequential re-hash
    theta: int = 1  # Θ
    phi: int = 1  # Φ

    # ---- derived ----
    @property
    def m_words(self) -> int:
        return 1 << self.log2_m_words

    @property
    def m_bits(self) -> int:
        return self.m_words * self.word_bits

    @property
    def s(self) -> int:
        """Words per block."""
        return self.block_bits // self.word_bits

    @property
    def num_blocks(self) -> int:
        return self.m_bits // self.block_bits

    @property
    def log2_num_blocks(self) -> int:
        return _log2(self.num_blocks)

    @property
    def log2_word_bits(self) -> int:
        return _log2(self.word_bits)

    @property
    def log2_block_bits(self) -> int:
        return _log2(self.block_bits)

    @property
    def log2_m_bits(self) -> int:
        return _log2(self.m_bits)

    @property
    def k_per_word(self) -> int:
        """SBF/RBBF: fingerprint bits per block word."""
        return self.k // self.s

    @property
    def k_per_group(self) -> int:
        """CSBF: fingerprint bits per sector group."""
        return self.k // self.z

    @property
    def sectors_per_group(self) -> int:
        """CSBF: candidate sectors per group."""
        return self.s // self.z

    @property
    def words_per_key(self) -> int:
        """P: how many (word, mask) probes one key generates."""
        if self.variant == "cbf":
            return self.k
        if self.variant in ("sbf", "rbbf"):
            return self.s
        if self.variant == "bbf":
            return self.k
        if self.variant == "csbf":
            return self.z
        raise ValueError(self.variant)

    @property
    def is_blocked(self) -> bool:
        return self.variant != "cbf"

    # ---- validation ----
    def validate(self) -> "FilterConfig":
        v = self.variant
        if v not in VARIANTS:
            raise ValueError(f"unknown variant {v!r}")
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}")
        if self.word_bits not in (32, 64):
            raise ValueError("word_bits must be 32 or 64")
        if not (0 < self.log2_m_words <= 34):
            raise ValueError("log2_m_words out of range")
        if not (1 <= self.k <= 62):
            raise ValueError("k must be in 1..=62 (salt table budget)")
        if self.scheme == "iter" and v != "bbf":
            raise ValueError("iter scheme models WarpCore's BBF only")
        if v == "cbf":
            if self.theta != 1 or self.phi != 1:
                raise ValueError("cbf has no block vectorization layout")
            return self
        if not _is_pow2(self.block_bits):
            raise ValueError("block_bits must be a power of two")
        if self.block_bits < self.word_bits:
            raise ValueError("block must hold at least one word")
        if self.block_bits > self.m_bits:
            raise ValueError("block larger than filter")
        if v == "rbbf" and self.block_bits != self.word_bits:
            raise ValueError("rbbf requires B == S")
        if v in ("sbf", "rbbf"):
            if self.k % self.s != 0 or self.k < self.s:
                raise ValueError("sbf requires k to be a positive multiple of s")
        if v == "csbf":
            if not _is_pow2(self.z) or self.z > self.s or self.z < 1:
                raise ValueError("csbf requires power-of-two z <= s")
            if self.k % self.z != 0:
                raise ValueError("csbf requires k % z == 0")
            if self.z > 16:
                raise ValueError("csbf group salt budget is 16")
        if not _is_pow2(self.theta) or not _is_pow2(self.phi):
            raise ValueError("theta and phi must be powers of two")
        if self.theta * self.phi > max(self.s, 1):
            raise ValueError("theta*phi must not exceed words per block")
        return self

    # ---- naming (mirrors rust & manifest) ----
    def name(self) -> str:
        parts = [self.variant, f"B{self.block_bits}", f"S{self.word_bits}", f"k{self.k}"]
        if self.variant == "csbf":
            parts.append(f"z{self.z}")
        if self.scheme != "mult":
            parts.append(self.scheme)
        parts.append(f"m{self.log2_m_words}")
        return "_".join(parts)

    def to_dict(self) -> dict:
        return {
            "variant": self.variant,
            "log2_m_words": self.log2_m_words,
            "word_bits": self.word_bits,
            "block_bits": self.block_bits,
            "k": self.k,
            "z": self.z,
            "scheme": self.scheme,
            "theta": self.theta,
            "phi": self.phi,
        }


# ---- the paper's accuracy math ----


def fpr_classic(m_bits: int, n: int, k: int) -> float:
    """Eq. (1): f = (1 - e^{-kn/m})^k."""
    if n == 0:
        return 0.0
    return (1.0 - math.exp(-k * n / m_bits)) ** k


def optimal_k(m_bits: int, n: int) -> int:
    """Eq. (2): k = (m/n) ln 2, rounded to the nearest positive integer."""
    return max(1, round(m_bits / n * math.log(2)))


def fpr_min(c: float) -> float:
    """Eq. (3): f_min = (1/2)^(c ln 2)."""
    return 0.5 ** (c * math.log(2))


def space_optimal_n(m_bits: int, k: int) -> int:
    """§5.1: the space-error-rate-optimal number of keys for a given (m, k).

    Solving Eq. (2) for n: k = (m/n) ln 2  =>  n = m ln 2 / k.
    """
    return max(1, int(m_bits * math.log(2) / k))


def fpr_blocked(m_bits: int, n: int, k: int, block_bits: int, terms: int = 64) -> float:
    """Putze et al.'s Poisson-mixture approximation for blocked filters.

    A block of B bits behaves as a classical Bloom filter loaded with a
    Poisson(n*B/m)-distributed number of keys; the blocked FPR is the
    expectation of Eq. (1) over that distribution.
    """
    if n == 0:
        return 0.0
    lam = n * block_bits / m_bits
    total, pmf = 0.0, math.exp(-lam)
    for i in range(terms):
        total += pmf * fpr_classic(block_bits, i, k)
        pmf *= lam / (i + 1)
    return total
