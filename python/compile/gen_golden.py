"""Generate artifacts/golden.json: the cross-language golden fixture.

The fixture pins the Rust hash/pattern/filter pipeline bit-for-bit to this
package's numpy oracle (ref.py / patterns.py / hashing.py). It is committed
at rust/artifacts/golden.json so `rust/tests/golden_cross_language.rs` runs
on every checkout without a build step; regenerate after any change to the
fingerprint pipeline on either side:

    cd python && python3 -m compile.gen_golden --out ../rust/artifacts/golden.json

Fixture schema (all u64 values are zero-padded lowercase hex strings):
    seed_base, salt_stream_seed  hash-pipeline constants
    salts                        the full 96-entry salt schedule
    keys                         the shared probe/insert key set
    base_hashes                  xxh64(key, SEED_BASE) per key
    cases[]                      per filter configuration:
        config                   the FilterConfig fields
        probes[]                 (key, word indices, word masks) samples
        inserted                 how many of `keys` were bulk-inserted
        filter_nonzero           [word index, word value] nonzero pairs
        contains                 0/1 lookup decision for every key
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .kernels import hashing as H
from .kernels import patterns, ref
from .params import FilterConfig

# Keys: a splitmix64 stream (a bijection over distinct states), so the set
# is distinct by construction and reproducible on both sides of the fence.
KEY_SEED = 0x601D_E2D5_EED0_0001
NUM_KEYS = 64
NUM_INSERTED = 40
NUM_PROBE_SAMPLES = 6

CASES = [
    FilterConfig(variant="sbf", log2_m_words=10, word_bits=64, block_bits=256, k=16),
    FilterConfig(variant="rbbf", log2_m_words=10, word_bits=64, block_bits=64, k=16),
    FilterConfig(variant="bbf", log2_m_words=10, word_bits=64, block_bits=256, k=16),
    FilterConfig(variant="bbf", log2_m_words=10, word_bits=64, block_bits=256, k=16, scheme="iter"),
    FilterConfig(variant="csbf", log2_m_words=10, word_bits=64, block_bits=512, k=16, z=2),
    FilterConfig(variant="cbf", log2_m_words=10, word_bits=64, block_bits=256, k=16),
    # S = 32 twins exercise the u32 engine
    FilterConfig(variant="sbf", log2_m_words=11, word_bits=32, block_bits=128, k=8),
    FilterConfig(variant="bbf", log2_m_words=11, word_bits=32, block_bits=256, k=16),
]


def hex64(x) -> str:
    return format(int(x) & H.MASK64, "016x")


def config_json(cfg: FilterConfig) -> dict:
    return {
        "variant": cfg.variant,
        "log2_m_words": cfg.log2_m_words,
        "word_bits": cfg.word_bits,
        "block_bits": cfg.block_bits,
        "k": cfg.k,
        "z": cfg.z,
        "scheme": cfg.scheme,
        "theta": cfg.theta,
        "phi": cfg.phi,
    }


def case_json(cfg: FilterConfig, keys: np.ndarray) -> dict:
    # probe samples: the raw (word index, mask) pattern per key
    probes = []
    for key in keys[:NUM_PROBE_SAMPLES]:
        word_idx, masks = patterns.gen_probes(cfg, np.array([key], dtype=np.uint64))
        probes.append(
            {
                "key": hex64(key),
                "words": [int(w) for w in word_idx[0]],
                "masks": [hex64(m) for m in masks[0]],
            }
        )

    # filter contents + lookup decisions after a partial bulk insert
    words = ref.new_filter(cfg)
    ref.add_ref(cfg, words, keys[:NUM_INSERTED])
    nonzero = [[int(i), hex64(w)] for i, w in enumerate(words) if int(w) != 0]
    contains = [int(b) for b in ref.contains_ref(cfg, words, keys)]
    # the oracle's own no-false-negative sanity check
    assert all(contains[:NUM_INSERTED]), f"oracle false negative for {cfg.variant}"
    return {
        "config": config_json(cfg),
        "probes": probes,
        "inserted": NUM_INSERTED,
        "filter_nonzero": nonzero,
        "contains": contains,
    }


def build() -> dict:
    raw = H._splitmix64_stream(KEY_SEED, NUM_KEYS)
    assert len(set(raw)) == NUM_KEYS
    keys = np.array(raw, dtype=np.uint64)
    base = H.xxh64_u64(keys)
    return {
        "_generated_by": "python -m compile.gen_golden (numpy oracle)",
        "seed_base": hex64(H.SEED_BASE),
        "salt_stream_seed": hex64(H.SALT_STREAM_SEED),
        "salts": [hex64(s) for s in H.SALTS],
        "keys": [hex64(k) for k in keys],
        "base_hashes": [hex64(h) for h in base],
        "cases": [case_json(cfg, keys) for cfg in CASES],
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out",
        type=Path,
        default=Path(__file__).resolve().parents[2] / "rust" / "artifacts" / "golden.json",
        help="output path (default: rust/artifacts/golden.json)",
    )
    args = parser.parse_args()
    doc = build()
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(doc, indent=1) + "\n")
    n_cases = len(doc["cases"])
    print(f"wrote {args.out} ({n_cases} cases, {len(doc['keys'])} keys)")


if __name__ == "__main__":
    main()
