"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT lowering.

Python in this package runs ONLY at build time (`make artifacts`); the Rust
coordinator executes the lowered HLO artifacts via PJRT at request time.

All filter arithmetic is on uint64 words/keys, so 64-bit mode is mandatory.
"""

import jax

jax.config.update("jax_enable_x64", True)
