"""L2: the JAX compute graph around the L1 kernels.

`build_op` returns a jit-able function for one (config, op, batch) triple.
Two implementations are provided:

  * impl="pallas"  - the L1 Pallas kernel (interpret mode), the default for
                     AOT artifacts; the paper's hot-spot lives here.
  * impl="jnp"     - the same computation expressed directly in jax.numpy;
                     used as an L2-level ablation artifact (bench: does the
                     kernelized version lower to leaner HLO?) and as a
                     correctness cross-check.

Either implementation lowers to a single HLO module per (config, op, batch)
via aot.py, which the Rust runtime loads and executes on the request path.

Operation signatures (fixed shapes, uint64 keys):
  contains: (filter[m_words], keys[batch])              -> hits  uint8[batch]
  add:      (keys[batch], n_valid[1] i32, filter[m..])  -> filter'[m_words]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import sbf_kernel
from .kernels.patterns import gen_block_masks, gen_probes
from .params import FilterConfig


def word_dtype(cfg: FilterConfig):
    return jnp.uint64 if cfg.word_bits == 64 else jnp.uint32


def contains_jnp(cfg: FilterConfig, batch: int):
    """Pure-jnp bulk lookup (gather + masked compare + structured all)."""

    def fn(words, keys):
        word_idx, masks = gen_probes(cfg, keys)
        masks = masks.astype(words.dtype)
        got = words[word_idx.reshape(-1)].reshape(batch, cfg.words_per_key)
        ok = (got & masks) == masks
        return sbf_kernel._structured_all(ok, cfg).astype(jnp.uint8)

    return fn


def add_jnp(cfg: FilterConfig, batch: int):
    """Pure-jnp bulk insert (sequential OR via fori_loop, no Pallas)."""
    s = cfg.s

    if cfg.is_blocked:

        def fn(keys, n_valid, words):
            bw0, mvec = gen_block_masks(cfg, keys)
            mvec = mvec.astype(words.dtype)

            def body(i, w):
                blk = jax.lax.dynamic_slice(w, (bw0[i],), (s,))
                return jax.lax.dynamic_update_slice(w, blk | mvec[i], (bw0[i],))

            return jax.lax.fori_loop(0, n_valid[0], body, words)

    else:

        def fn(keys, n_valid, words):
            word_idx, masks = gen_probes(cfg, keys)
            masks = masks.astype(words.dtype)

            def body(i, w):
                for p in range(cfg.k):
                    cur = jax.lax.dynamic_slice(w, (word_idx[i, p],), (1,))
                    w = jax.lax.dynamic_update_slice(w, cur | masks[i, p : p + 1], (word_idx[i, p],))
                return w

            return jax.lax.fori_loop(0, n_valid[0], body, words)

    return fn


def build_op(cfg: FilterConfig, op: str, batch: int, impl: str = "pallas"):
    """Return the callable for one artifact; see module docstring for sigs."""
    cfg.validate()
    if impl == "pallas":
        if op == "contains":
            return sbf_kernel.make_contains(cfg, batch)
        if op == "add":
            return sbf_kernel.make_add(cfg, batch)
    elif impl == "jnp":
        if op == "contains":
            return contains_jnp(cfg, batch)
        if op == "add":
            return add_jnp(cfg, batch)
    raise ValueError(f"unknown op/impl {op!r}/{impl!r}")


def abstract_inputs(cfg: FilterConfig, op: str, batch: int):
    """ShapeDtypeStructs matching build_op's calling convention."""
    words = jax.ShapeDtypeStruct((cfg.m_words,), word_dtype(cfg))
    keys = jax.ShapeDtypeStruct((batch,), jnp.uint64)
    n_valid = jax.ShapeDtypeStruct((1,), jnp.int32)
    if op == "contains":
        return (words, keys)
    if op == "add":
        return (keys, n_valid, words)
    raise ValueError(op)
