import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def random_keys(rng, n: int) -> np.ndarray:
    """Distinct-ish random uint64 keys (collision probability negligible)."""
    return rng.integers(0, np.iinfo(np.int64).max, size=n).astype(np.uint64)
