"""Semantics tests for every filter variant against the numpy oracle."""

import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.patterns import gen_block_masks, gen_probes
from compile.params import FilterConfig, fpr_blocked, fpr_classic, fpr_min, optimal_k, space_optimal_n

from conftest import random_keys

ALL_CONFIGS = [
    FilterConfig(variant="sbf", block_bits=256, k=16, log2_m_words=12),
    FilterConfig(variant="sbf", block_bits=512, k=8, log2_m_words=12),
    FilterConfig(variant="sbf", block_bits=1024, k=16, log2_m_words=12),
    FilterConfig(variant="rbbf", block_bits=64, k=16, log2_m_words=12),
    FilterConfig(variant="rbbf", block_bits=64, k=4, log2_m_words=12),
    FilterConfig(variant="csbf", block_bits=512, k=16, z=2, log2_m_words=12),
    FilterConfig(variant="csbf", block_bits=1024, k=16, z=4, log2_m_words=12),
    FilterConfig(variant="csbf", block_bits=1024, k=8, z=8, log2_m_words=12),
    FilterConfig(variant="bbf", block_bits=256, k=16, log2_m_words=12),
    FilterConfig(variant="bbf", block_bits=256, k=16, scheme="iter", log2_m_words=12),
    FilterConfig(variant="cbf", k=16, log2_m_words=12),
    FilterConfig(variant="cbf", k=7, log2_m_words=12),
    FilterConfig(variant="sbf", block_bits=128, word_bits=32, k=8, log2_m_words=12),
    FilterConfig(variant="rbbf", block_bits=32, word_bits=32, k=4, log2_m_words=12),
]

IDS = [c.name() for c in ALL_CONFIGS]


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=IDS)
def test_no_false_negatives(cfg, rng):
    """The defining Bloom filter property: inserted keys always hit."""
    cfg.validate()
    keys = random_keys(rng, 2000)
    words = ref.new_filter(cfg)
    ref.add_ref(cfg, words, keys)
    assert ref.contains_ref(cfg, words, keys).all()


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=IDS)
def test_empty_filter_rejects_everything(cfg, rng):
    cfg.validate()
    keys = random_keys(rng, 500)
    words = ref.new_filter(cfg)
    assert not ref.contains_ref(cfg, words, keys).any()


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=IDS)
def test_probe_geometry(cfg, rng):
    """Word indices in range; masks nonzero, within word width, and with at
    most k set bits total; blocked variants stay inside one block."""
    cfg.validate()
    keys = random_keys(rng, 512)
    word_idx, masks = gen_probes(cfg, keys)
    n, P = word_idx.shape
    assert P == cfg.words_per_key
    assert word_idx.min() >= 0 and word_idx.max() < cfg.m_words
    assert (masks != 0).all()
    if cfg.word_bits == 32:
        assert (masks >> np.uint64(32) == 0).all()
    popcnt = np.vectorize(lambda x: bin(int(x)).count("1"))(masks)
    assert (popcnt.sum(axis=1) <= cfg.k).all()
    assert (popcnt.sum(axis=1) >= 1).all()
    if cfg.is_blocked:
        blk = word_idx // cfg.s
        assert (blk == blk[:, :1]).all(), "probes must stay inside one block"


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=IDS)
def test_add_idempotent(cfg, rng):
    cfg.validate()
    keys = random_keys(rng, 300)
    w1 = ref.new_filter(cfg)
    ref.add_ref(cfg, w1, keys)
    w2 = w1.copy()
    ref.add_ref(cfg, w2, keys)
    np.testing.assert_array_equal(w1, w2)


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=IDS)
def test_add_order_invariant(cfg, rng):
    cfg.validate()
    keys = random_keys(rng, 300)
    w1 = ref.new_filter(cfg)
    ref.add_ref(cfg, w1, keys)
    w2 = ref.new_filter(cfg)
    ref.add_ref(cfg, w2, keys[::-1].copy())
    np.testing.assert_array_equal(w1, w2)


@pytest.mark.parametrize("cfg", ALL_CONFIGS, ids=IDS)
def test_block_masks_equal_probes(cfg, rng):
    """gen_block_masks (the insert-kernel shape) must encode exactly the
    probe set of gen_probes."""
    if not cfg.is_blocked:
        pytest.skip("cbf has no block masks")
    cfg.validate()
    keys = random_keys(rng, 256)
    bw0, mvec = gen_block_masks(cfg, keys)
    word_idx, masks = gen_probes(cfg, keys)
    dense = np.zeros((len(keys), cfg.s), dtype=np.uint64)
    for i in range(len(keys)):
        for p in range(masks.shape[1]):
            dense[i, word_idx[i, p] - bw0[i]] |= masks[i, p]
    np.testing.assert_array_equal(np.asarray(mvec, dtype=np.uint64), dense)
    assert (bw0 % cfg.s == 0).all()


def test_sbf_spreads_bits_evenly(rng):
    """SBF: every word of the block receives exactly k/s bits (<= collisions)."""
    cfg = FilterConfig(variant="sbf", block_bits=256, k=16, log2_m_words=12).validate()
    keys = random_keys(rng, 200)
    _, masks = gen_probes(cfg, keys)
    popcnt = np.vectorize(lambda x: bin(int(x)).count("1"))(masks)
    assert (popcnt <= cfg.k_per_word).all()
    assert (popcnt >= 1).all()


def test_csbf_group_structure(rng):
    """CSBF: probe g lands in group g's sector range."""
    cfg = FilterConfig(variant="csbf", block_bits=1024, k=16, z=4, log2_m_words=12).validate()
    keys = random_keys(rng, 300)
    word_idx, _ = gen_probes(cfg, keys)
    local = word_idx % cfg.s
    spg = cfg.sectors_per_group
    for g in range(cfg.z):
        assert (local[:, g] >= g * spg).all()
        assert (local[:, g] < (g + 1) * spg).all()


def test_variant_fprs_are_ordered(rng):
    """At equal size/k, measured FPR: CBF < SBF(large B) <= SBF(256) < RBBF."""
    m, k = 12, 16
    n_ins = space_optimal_n((1 << m) * 64, k)
    fprs = {}
    for name, cfg in {
        "cbf": FilterConfig(variant="cbf", k=k, log2_m_words=m),
        "sbf256": FilterConfig(variant="sbf", block_bits=256, k=k, log2_m_words=m),
        "rbbf": FilterConfig(variant="rbbf", block_bits=64, k=k, log2_m_words=m),
    }.items():
        fprs[name] = ref.measure_fpr(cfg.validate(), n_ins, 20000)
    assert fprs["cbf"] < fprs["sbf256"] < fprs["rbbf"], fprs


def test_fpr_matches_theory():
    """Measured CBF FPR tracks Eq. (1) within noise."""
    cfg = FilterConfig(variant="cbf", k=8, log2_m_words=12).validate()
    n = space_optimal_n(cfg.m_bits, cfg.k)
    measured = ref.measure_fpr(cfg, n, 40000)
    theory = fpr_classic(cfg.m_bits, n, cfg.k)
    assert theory / 3 < max(measured, 1e-9) < theory * 3, (measured, theory)


def test_blocked_fpr_approximation():
    """Putze Poisson mixture: blocked FPR above classical, below 4x for B=512."""
    m_bits = (1 << 12) * 64
    k = 8
    n = space_optimal_n(m_bits, k)
    f_c = fpr_classic(m_bits, n, k)
    f_b = fpr_blocked(m_bits, n, k, 512)
    assert f_c < f_b < 40 * f_c


def test_eq2_eq3_consistency():
    for c in (8, 12, 16, 23):
        k = optimal_k(c * 1000, 1000)
        assert abs(k - c * np.log(2)) <= 0.51
        assert 0 < fpr_min(c) < 1


def test_space_optimal_n_roundtrip():
    m_bits = 1 << 20
    for k in (4, 8, 16):
        n = space_optimal_n(m_bits, k)
        # at the space-optimal load, bits-per-key * ln2 ~= k
        assert abs(m_bits / n * np.log(2) - k) < 0.01 * k


@pytest.mark.parametrize(
    "bad",
    [
        dict(variant="sbf", block_bits=256, k=15),  # k % s != 0
        dict(variant="sbf", block_bits=192, k=12),  # B not pow2
        dict(variant="rbbf", block_bits=128, k=16),  # B != S
        dict(variant="csbf", block_bits=512, k=16, z=3),  # z not pow2
        dict(variant="csbf", block_bits=512, k=15, z=2),  # k % z != 0
        dict(variant="cbf", k=16, theta=2),  # cbf has no layout
        dict(variant="sbf", block_bits=256, k=16, theta=8, phi=2),  # theta*phi > s
        dict(variant="sbf", block_bits=256, k=16, scheme="iter"),  # iter is bbf-only
        dict(variant="bbf", block_bits=256, k=0),
        dict(variant="nope"),
    ],
)
def test_validation_rejects(bad):
    with pytest.raises(ValueError):
        FilterConfig(**bad).validate()
