"""Pallas kernel vs numpy oracle - the CORE L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref, sbf_kernel
from compile.params import FilterConfig

from conftest import random_keys

KCONFIGS = [
    FilterConfig(variant="sbf", block_bits=256, k=16, log2_m_words=10),
    FilterConfig(variant="sbf", block_bits=256, k=16, theta=2, phi=2, log2_m_words=10),
    FilterConfig(variant="sbf", block_bits=1024, k=16, theta=4, phi=4, log2_m_words=10),
    FilterConfig(variant="rbbf", block_bits=64, k=16, log2_m_words=10),
    FilterConfig(variant="csbf", block_bits=512, k=16, z=2, log2_m_words=10),
    FilterConfig(variant="bbf", block_bits=256, k=16, log2_m_words=10),
    FilterConfig(variant="bbf", block_bits=256, k=16, scheme="iter", log2_m_words=10),
    FilterConfig(variant="cbf", k=16, log2_m_words=10),
    FilterConfig(variant="sbf", block_bits=128, word_bits=32, k=8, log2_m_words=10),
]
IDS = [c.name() + (f"_t{c.theta}p{c.phi}" if c.theta * c.phi > 1 else "") for c in KCONFIGS]

BATCH = 128


def _mk_filter(cfg, rng, fill=200):
    keys = random_keys(rng, fill)
    words = ref.new_filter(cfg)
    ref.add_ref(cfg, words, keys)
    return words, keys


@pytest.mark.parametrize("cfg", KCONFIGS, ids=IDS)
def test_contains_kernel_matches_ref(cfg, rng):
    cfg.validate()
    words, inserted = _mk_filter(cfg, rng)
    queries = np.concatenate([inserted[:BATCH // 2], random_keys(rng, BATCH - BATCH // 2)])
    fn = sbf_kernel.make_contains(cfg, BATCH)
    got = np.asarray(fn(jnp.asarray(words), jnp.asarray(queries)))
    want = ref.contains_ref(cfg, words, queries).astype(np.uint8)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", KCONFIGS, ids=IDS)
def test_add_kernel_matches_ref(cfg, rng):
    cfg.validate()
    keys = random_keys(rng, BATCH)
    fn = sbf_kernel.make_add(cfg, BATCH)
    got = np.asarray(
        fn(jnp.asarray(keys), jnp.array([BATCH], dtype=jnp.int32), jnp.asarray(ref.new_filter(cfg)))
    )
    want = ref.add_ref(cfg, ref.new_filter(cfg), keys)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cfg", KCONFIGS, ids=IDS)
def test_add_kernel_respects_n_valid(cfg, rng):
    """Padding keys beyond n_valid must not touch the filter."""
    cfg.validate()
    keys = random_keys(rng, BATCH)
    n_valid = 37
    fn = sbf_kernel.make_add(cfg, BATCH)
    got = np.asarray(
        fn(jnp.asarray(keys), jnp.array([n_valid], dtype=jnp.int32), jnp.asarray(ref.new_filter(cfg)))
    )
    want = ref.add_ref(cfg, ref.new_filter(cfg), keys[:n_valid])
    np.testing.assert_array_equal(got, want)


def test_add_kernel_accumulates(rng):
    """Two sequential bulk adds == one combined add."""
    cfg = KCONFIGS[0].validate()
    k1, k2 = random_keys(rng, BATCH), random_keys(rng, BATCH)
    fn = sbf_kernel.make_add(cfg, BATCH)
    nv = jnp.array([BATCH], dtype=jnp.int32)
    f1 = fn(jnp.asarray(k1), nv, jnp.asarray(ref.new_filter(cfg)))
    f2 = np.asarray(fn(jnp.asarray(k2), nv, f1))
    want = ref.add_ref(cfg, ref.add_ref(cfg, ref.new_filter(cfg), k1), k2)
    np.testing.assert_array_equal(f2, want)


THETA_PHI_LAYOUTS = [(1, 1), (1, 4), (2, 2), (4, 1), (2, 1), (1, 2)]


@pytest.mark.parametrize("theta,phi", THETA_PHI_LAYOUTS)
def test_layouts_bit_identical(theta, phi, rng):
    """Paper §4.1: the (Θ, Φ) layout is a performance knob, never a
    semantics knob - every layout must return identical results."""
    base = FilterConfig(variant="sbf", block_bits=256, k=16, log2_m_words=10)
    cfg = FilterConfig(**{**base.to_dict(), "theta": theta, "phi": phi}).validate()
    words, inserted = _mk_filter(cfg, rng)
    queries = np.concatenate([inserted[:64], random_keys(rng, 64)])
    fn = sbf_kernel.make_contains(cfg, BATCH)
    got = np.asarray(fn(jnp.asarray(words), jnp.asarray(queries)))
    ref_fn = sbf_kernel.make_contains(base.validate(), BATCH)
    want = np.asarray(ref_fn(jnp.asarray(words), jnp.asarray(queries)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("op", ["contains", "add"])
def test_jnp_impl_matches_pallas(op, rng):
    """L2 ablation implementation == L1 kernel."""
    cfg = KCONFIGS[0].validate()
    keys = random_keys(rng, BATCH)
    words, _ = _mk_filter(cfg, rng)
    pallas_fn = model.build_op(cfg, op, BATCH, impl="pallas")
    jnp_fn = model.build_op(cfg, op, BATCH, impl="jnp")
    if op == "contains":
        args = (jnp.asarray(words), jnp.asarray(keys))
    else:
        args = (jnp.asarray(keys), jnp.array([BATCH], dtype=jnp.int32), jnp.asarray(words))
    np.testing.assert_array_equal(np.asarray(pallas_fn(*args)), np.asarray(jnp_fn(*args)))


def test_kernel_no_false_negatives_end_to_end(rng):
    """Insert through the add kernel, query through the contains kernel."""
    cfg = KCONFIGS[0].validate()
    keys = random_keys(rng, BATCH)
    add = sbf_kernel.make_add(cfg, BATCH)
    contains = sbf_kernel.make_contains(cfg, BATCH)
    words = add(jnp.asarray(keys), jnp.array([BATCH], dtype=jnp.int32), jnp.asarray(ref.new_filter(cfg)))
    hits = np.asarray(contains(words, jnp.asarray(keys)))
    assert (hits == 1).all()
