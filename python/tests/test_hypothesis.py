"""Hypothesis property sweeps over kernel shapes, dtypes, and configs.

The randomized counterpart of test_kernels.py: configurations, key sets,
batch sizes and (Θ, Φ) layouts are drawn by hypothesis; every draw must
keep the Pallas kernels equal to the numpy oracle.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sbf_kernel
from compile.kernels import hashing as H
from compile.kernels.patterns import gen_probes
from compile.params import FilterConfig

# keep runtimes CI-friendly: small filters, modest batches, few examples
SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def filter_configs(draw):
    variant = draw(st.sampled_from(["sbf", "rbbf", "csbf", "bbf", "cbf"]))
    word_bits = draw(st.sampled_from([32, 64]))
    if variant == "rbbf":
        block_bits = word_bits
    elif variant == "cbf":
        block_bits = 256
    else:
        block_bits = word_bits * draw(st.sampled_from([1, 2, 4, 8, 16]))
    block_bits = min(block_bits, 1024)
    s = max(1, block_bits // word_bits)
    if variant in ("sbf", "rbbf"):
        k = s * draw(st.integers(1, max(1, min(4, 48 // s))))
    elif variant == "csbf":
        k = 16
    else:
        k = draw(st.integers(1, 20))
    z = draw(st.sampled_from([zz for zz in (1, 2, 4, 8) if zz <= s])) if variant == "csbf" else 1
    scheme = draw(st.sampled_from(["mult", "iter"])) if variant == "bbf" else "mult"
    cfg = FilterConfig(
        variant=variant,
        word_bits=word_bits,
        block_bits=block_bits,
        k=min(k, 62),
        z=z,
        scheme=scheme,
        log2_m_words=draw(st.integers(8, 11)),
    )
    return cfg.validate()


def keys_array(seed: int, n: int) -> np.ndarray:
    return np.array(H._splitmix64_stream(seed ^ 0xABCDEF, n), dtype=np.uint64)


@given(cfg=filter_configs(), seed=st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_contains_kernel_matches_oracle(cfg, seed):
    batch = 64
    ins = keys_array(seed, 100)
    words = ref.new_filter(cfg)
    ref.add_ref(cfg, words, ins)
    queries = np.concatenate([ins[: batch // 2], keys_array(seed + 1, batch - batch // 2)])
    fn = sbf_kernel.make_contains(cfg, batch)
    got = np.asarray(fn(jnp.asarray(words), jnp.asarray(queries)))
    want = ref.contains_ref(cfg, words, queries).astype(np.uint8)
    np.testing.assert_array_equal(got, want)


@given(cfg=filter_configs(), seed=st.integers(0, 2**32 - 1), n_valid=st.integers(0, 64))
@settings(**SETTINGS)
def test_add_kernel_matches_oracle_with_padding(cfg, seed, n_valid):
    batch = 64
    keys = keys_array(seed, batch)
    fn = sbf_kernel.make_add(cfg, batch)
    got = np.asarray(
        fn(jnp.asarray(keys), jnp.array([n_valid], dtype=jnp.int32), jnp.asarray(ref.new_filter(cfg)))
    )
    want = ref.add_ref(cfg, ref.new_filter(cfg), keys[:n_valid])
    np.testing.assert_array_equal(got, want)


@given(
    seed=st.integers(0, 2**32 - 1),
    theta=st.sampled_from([1, 2, 4]),
    phi=st.sampled_from([1, 2, 4]),
)
@settings(**SETTINGS)
def test_theta_phi_layouts_bit_identical(seed, theta, phi):
    base = FilterConfig(variant="sbf", block_bits=1024, k=16, log2_m_words=10)
    cfg = FilterConfig(**{**base.to_dict(), "theta": theta, "phi": phi}).validate()
    ins = keys_array(seed, 80)
    words = ref.new_filter(cfg)
    ref.add_ref(cfg, words, ins)
    queries = np.concatenate([ins[:32], keys_array(seed + 7, 32)])
    got = np.asarray(sbf_kernel.make_contains(cfg, 64)(jnp.asarray(words), jnp.asarray(queries)))
    want = np.asarray(
        sbf_kernel.make_contains(base.validate(), 64)(jnp.asarray(words), jnp.asarray(queries))
    )
    np.testing.assert_array_equal(got, want)


@given(cfg=filter_configs(), seed=st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_probe_geometry_invariants(cfg, seed):
    keys = keys_array(seed, 64)
    word_idx, masks = gen_probes(cfg, keys)
    assert word_idx.shape == (64, cfg.words_per_key)
    assert word_idx.min() >= 0 and word_idx.max() < cfg.m_words
    assert (masks != 0).all()
    if cfg.word_bits == 32:
        assert (masks >> np.uint64(32) == 0).all()
    if cfg.is_blocked:
        blk = word_idx // cfg.s
        assert (blk == blk[:, :1]).all()


@given(cfg=filter_configs(), seed=st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_oracle_no_false_negatives_and_order_invariance(cfg, seed):
    keys = keys_array(seed, 200)
    w1 = ref.add_ref(cfg, ref.new_filter(cfg), keys)
    assert ref.contains_ref(cfg, w1, keys).all()
    w2 = ref.add_ref(cfg, ref.new_filter(cfg), keys[::-1].copy())
    np.testing.assert_array_equal(w1, w2)
