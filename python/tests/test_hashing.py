"""Unit tests for the fingerprint pipeline (hashing.py)."""

import numpy as np
import pytest

from compile.kernels import hashing as H


MASK = (1 << 64) - 1


def _rotl(x, r):
    return ((x << r) | (x >> (64 - r))) & MASK


def _xxh64_reference(data: bytes, seed: int = 0) -> int:
    """Independent scalar XXH64 (full spec, short-input path) for cross-check.

    Written from the published algorithm description, not from
    compile/kernels/hashing.py; for len(data) < 32 the stripe loop is
    skipped and h64 starts from seed + PRIME5.
    """
    p1, p2, p3, p4, p5 = (
        H.XXH_PRIME64_1,
        H.XXH_PRIME64_2,
        H.XXH_PRIME64_3,
        H.XXH_PRIME64_4,
        H.XXH_PRIME64_5,
    )
    assert len(data) < 32, "test helper covers the short-input path only"
    h = (seed + p5 + len(data)) & MASK
    i = 0
    while i + 8 <= len(data):
        k1 = int.from_bytes(data[i : i + 8], "little")
        k1 = (k1 * p2) & MASK
        k1 = _rotl(k1, 31)
        k1 = (k1 * p1) & MASK
        h ^= k1
        h = (_rotl(h, 27) * p1 + p4) & MASK
        i += 8
    while i + 4 <= len(data):
        h ^= (int.from_bytes(data[i : i + 4], "little") * p1) & MASK
        h = (_rotl(h, 23) * p2 + p3) & MASK
        i += 4
    while i < len(data):
        h ^= (data[i] * p5) & MASK
        h = (_rotl(h, 11) * p1) & MASK
        i += 1
    h ^= h >> 33
    h = (h * p2) & MASK
    h ^= h >> 29
    h = (h * p3) & MASK
    h ^= h >> 32
    return h


def test_xxh64_matches_independent_reference():
    """Our vectorized 8-byte specialization == the general XXH64 algorithm."""
    rng = np.random.default_rng(99)
    keys = list(rng.integers(0, 2**63, size=200, dtype=np.uint64)) + [
        np.uint64(0),
        np.uint64(MASK),
        np.uint64(1),
    ]
    for seed in (0, 1, H.SEED_BASE):
        for key in keys[:50]:
            want = _xxh64_reference(int(key).to_bytes(8, "little"), seed=seed)
            got = int(H.xxh64_u64(np.uint64(key), seed=seed))
            assert got == want, f"key={int(key):#x} seed={seed:#x}"


def test_xxh64_array_matches_scalar():
    keys = np.arange(100, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    vec = H.xxh64_u64(keys)
    for i, k in enumerate(keys):
        assert vec[i] == H.xxh64_u64(k)


def test_xxh64_avalanche():
    """Flipping any single input bit should flip ~half the output bits."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**63, size=64, dtype=np.uint64)
    h0 = H.xxh64_u64(keys)
    flips = []
    for bit in range(64):
        h1 = H.xxh64_u64(keys ^ np.uint64(1 << bit))
        flips.append(np.mean([bin(int(a ^ b)).count("1") for a, b in zip(h0, h1)]))
    assert 24 < np.mean(flips) < 40


def test_salts_are_odd_and_distinct():
    assert len(set(H.SALTS)) == len(H.SALTS)
    assert all(s & 1 for s in H.SALTS)
    assert all(0 < s < 2**64 for s in H.SALTS)


def test_salt_roles_disjoint():
    roles = [H.salt_block()] + [H.salt_group(g) for g in range(16)] + [H.salt_bit(i) for i in range(62)]
    assert len(set(roles)) == len(roles)


def test_tophash_range():
    base = H.xxh64_u64(np.arange(1000, dtype=np.uint64))
    for nbits in (1, 3, 6, 10, 20):
        t = H.tophash(base, H.salt_bit(0), nbits)
        assert t.max() < (1 << nbits)
        assert t.min() >= 0


def test_tophash_zero_bits():
    base = H.xxh64_u64(np.arange(10, dtype=np.uint64))
    assert (H.tophash(base, H.salt_bit(0), 0) == 0).all()


def test_tophash_uniformity():
    """Top-bit multiplicative hashing should be close to uniform (chi^2)."""
    base = H.xxh64_u64(np.arange(1 << 14, dtype=np.uint64))
    buckets = 64
    t = H.tophash(base, H.salt_bit(3), 6)
    counts = np.bincount(t.astype(np.int64), minlength=buckets)
    expected = len(base) / buckets
    chi2 = ((counts - expected) ** 2 / expected).sum()
    # dof=63; p=0.001 critical value ~ 103. Allow generous slack.
    assert chi2 < 120, f"chi2={chi2}"


def test_iter_chain_sequential_dependency():
    base = H.xxh64_u64(np.arange(16, dtype=np.uint64))
    pos = H.iter_chain(base, 4, 8)
    assert len(pos) == 4
    assert all(p.max() < 256 for p in pos)
    # successive positions must differ somewhere (chain actually advances)
    assert any((pos[0] != pos[i]).any() for i in range(1, 4))


def test_jnp_matches_numpy():
    import jax.numpy as jnp

    keys = np.arange(256, dtype=np.uint64) * np.uint64(0xDEADBEEFCAFEF00D)
    np_h = H.xxh64_u64(keys)
    j_h = np.asarray(H.xxh64_u64(jnp.asarray(keys)))
    np.testing.assert_array_equal(np_h, j_h)
