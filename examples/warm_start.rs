//! Warm start: snapshot a populated multi-tenant catalog, "restart" the
//! process (a brand-new `FilterService`), restore, and verify — first
//! in-process, then the same restore driven over the wire transport.
//!
//!     cargo run --release --example warm_start
//!
//! The point: bulk construction is the expensive part (the paper's 15.4×
//! headline is exactly about making it fast), so a production catalog
//! should pay it once and warm-start from disk on every later boot.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use gbf::coordinator::{FilterService, GbfError, RemoteFilterService, WireServer};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::workload::keygen::unique_keys;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("GBF_BENCH_QUICK").is_ok();
    let n_hot = if quick { 20_000 } else { 400_000 };
    let n_cold = n_hot / 4;
    let state_dir = std::env::temp_dir().join(format!("gbf-warm-start-{}", std::process::id()));

    // ---- boot 1: build and populate two tenants, snapshot, "shut down" ----
    let service = FilterService::new();
    let hot = service.create_filter(
        "hot",
        FilterConfig { log2_m_words: if quick { 14 } else { 18 }, ..Default::default() },
        4,
    )?;
    let cold = service.create_filter(
        "cold",
        FilterConfig { variant: Variant::Bbf, log2_m_words: 13, ..Default::default() },
        2,
    )?;
    let hot_keys = unique_keys(n_hot, 0xA1);
    let cold_keys = unique_keys(n_cold, 0xB2);
    let t0 = Instant::now();
    let t_hot = hot.add_bulk(&hot_keys);
    let t_cold = cold.add_bulk(&cold_keys);
    t_hot.wait()?;
    t_cold.wait()?;
    let build = t0.elapsed();

    let t1 = Instant::now();
    for name in ["hot", "cold"] {
        service.snapshot(name, &state_dir.join(name))?;
    }
    println!("boot 1: built {} keys in {build:?}, snapshotted both tenants in {:?}", n_hot + n_cold, t1.elapsed());
    let hot_words = hot.snapshot_words();
    drop(service); // the "restart"

    // ---- boot 2: a fresh catalog warm-starts from disk ----
    let service = FilterService::new();
    let t2 = Instant::now();
    let hot2 = service.restore("hot", &state_dir.join("hot"))?;
    let cold2 = service.restore("cold", &state_dir.join("cold"))?;
    println!("boot 2: restored both tenants in {:?} (vs {build:?} to rebuild)", t2.elapsed());
    assert_eq!(hot2.snapshot_words(), hot_words, "byte-identical state across the restart");
    assert!(hot2.query_bulk(&hot_keys).wait()?.iter().all(|&h| h), "no false negatives after restore");
    assert!(cold2.query_bulk(&cold_keys).wait()?.iter().all(|&h| h));
    assert_eq!(service.stats("hot")?.metrics.adds, n_hot as u64, "key counters survive the restart");

    // a corrupt snapshot is a typed refusal, never a panic
    match service.restore("hot2", &state_dir.join("nope")) {
        Err(GbfError::SnapshotCorrupt(_)) => println!("missing snapshot refused with a typed error"),
        other => anyhow::bail!("expected SnapshotCorrupt, got {other:?}"),
    }

    // ---- the same restore, driven over the wire ----
    // Paths resolve server-side: the client ships names and paths only,
    // so restoring a multi-GiB tenant costs one small frame each way.
    let remote_catalog = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&remote_catalog), "127.0.0.1:0")?;
    let client = RemoteFilterService::connect(server.local_addr())?;
    let t3 = Instant::now();
    let remote_hot = client.restore("hot", path_str(&state_dir.join("hot"))?)?;
    println!("wire restore in {:?}", t3.elapsed());
    assert!(remote_hot.query_bulk(&hot_keys[..1_000]).wait()?.iter().all(|&h| h));
    let server_side = remote_catalog.handle("hot")?;
    assert_eq!(server_side.snapshot_words(), hot_words, "wire-restored state is byte-identical too");
    client.snapshot("hot", path_str(&state_dir.join("hot-remote"))?)?;
    println!("wire snapshot written server-side; catalog now serves {:?}", client.list_filters()?);

    std::fs::remove_dir_all(&state_dir).ok();
    println!("warm start OK");
    Ok(())
}

fn path_str(p: &Path) -> anyhow::Result<&str> {
    p.to_str().ok_or_else(|| anyhow::anyhow!("non-UTF-8 temp path"))
}
