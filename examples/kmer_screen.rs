//! Genomics workload (paper §1: k-mer counting / read classification):
//! build a filter over a reference genome's canonical 21-mers, then screen
//! sequencing reads for contamination — reads whose k-mers mostly miss the
//! reference are flagged as foreign.
//!
//!     cargo run --release --example kmer_screen

use std::time::Instant;

use gbf::filter::params::{optimal_k, FilterConfig, Variant};
use gbf::filter::AnyBloom;
use gbf::workload::kmer::{extract_kmers, mutate_reads, random_sequence};

const K: usize = 21;

fn main() -> anyhow::Result<()> {
    // synthetic "reference genome" + read sets
    let reference = random_sequence(2_000_000, 7);
    let clean_reads = mutate_reads(&reference, 2_000, 150, 0.002, 8); // sequencing noise
    let foreign = random_sequence(1_000_000, 99); // contaminant source
    let contam_reads = mutate_reads(&foreign, 2_000, 150, 0.002, 9);

    // index the reference 21-mers
    let mut ref_kmers = Vec::new();
    extract_kmers(&reference, K, &mut ref_kmers);
    println!("reference: {} bp, {} canonical {K}-mers", reference.len(), ref_kmers.len());

    // pick a filter sized ~12 bits per k-mer with the Eq.(2)-optimal k
    let m_bits_target = (ref_kmers.len() * 12).next_power_of_two() as u64;
    let log2_m_words = (m_bits_target / 64).trailing_zeros();
    let k = optimal_k(m_bits_target, ref_kmers.len() as u64).min(16);
    let cfg = FilterConfig {
        variant: Variant::Sbf,
        block_bits: 256,
        k: k.max(4) / 4 * 4, // SBF wants k % s == 0 (s = 4)
        log2_m_words,
        ..Default::default()
    }
    .validate()?;
    let filter = AnyBloom::new(cfg)?;
    let t0 = Instant::now();
    filter.bulk_add(&ref_kmers, 0);
    println!(
        "built {} in {:?} ({:.1} M kmers/s), fill {:.1}%",
        cfg.name(),
        t0.elapsed(),
        ref_kmers.len() as f64 / t0.elapsed().as_secs_f64() / 1e6,
        filter.fill_ratio() * 100.0
    );

    // screen both read sets: fraction of read k-mers present in reference
    let screen = |reads: &[Vec<u8>]| -> (f64, usize) {
        let mut total_ratio = 0.0;
        let mut flagged = 0;
        let mut kmers = Vec::new();
        for read in reads {
            kmers.clear();
            extract_kmers(read, K, &mut kmers);
            if kmers.is_empty() {
                continue;
            }
            let hits = filter.bulk_contains(&kmers, 1).iter().filter(|&&h| h).count();
            let ratio = hits as f64 / kmers.len() as f64;
            total_ratio += ratio;
            if ratio < 0.5 {
                flagged += 1; // contamination call
            }
        }
        (total_ratio / reads.len() as f64, flagged)
    };

    let t1 = Instant::now();
    let (clean_ratio, clean_flagged) = screen(&clean_reads);
    let (contam_ratio, contam_flagged) = screen(&contam_reads);
    let n_kmers = (clean_reads.len() + contam_reads.len()) * (150 - K + 1);
    println!(
        "screened {} reads ({} k-mer lookups) in {:?}",
        clean_reads.len() + contam_reads.len(),
        n_kmers,
        t1.elapsed()
    );
    println!("clean reads  : mean hit-ratio {clean_ratio:.3}, flagged {clean_flagged}/2000");
    println!("contam reads : mean hit-ratio {contam_ratio:.3}, flagged {contam_flagged}/2000");

    anyhow::ensure!(clean_flagged < 20, "clean reads should pass");
    anyhow::ensure!(contam_flagged > 1980, "contaminants should be flagged");
    println!("classification OK: no false negatives on reference k-mers, contaminants separated");
    Ok(())
}
