//! Genomics workload (paper §1, cuSBF-style): **one filter namespace per
//! sequencing sample**. Each sample's read k-mers are indexed into its own
//! filter on a shared `FilterService`; marker sequences are then screened
//! against every sample *concurrently* (tickets in flight together) to
//! build a presence/absence matrix — which samples carry the reference
//! organism, which carry the contaminant.
//!
//!     cargo run --release --example kmer_screen

use std::time::Instant;

use gbf::coordinator::{FilterHandle, FilterService};
use gbf::filter::params::{optimal_k, FilterConfig, Variant};
use gbf::workload::kmer::{extract_kmers, mutate_reads, random_sequence};

const K: usize = 21;
const READS_PER_SAMPLE: usize = 4_000;
const READ_LEN: usize = 150;

/// Size a filter for `n` k-mers at ~12 bits each, with the Eq.(2)-optimal
/// k rounded to SBF's sectorization constraint (k % 4 == 0).
fn sample_config(n_kmers: usize) -> anyhow::Result<FilterConfig> {
    let m_bits_target = (n_kmers * 12).next_power_of_two() as u64;
    let log2_m_words = (m_bits_target / 64).trailing_zeros();
    let k = optimal_k(m_bits_target, n_kmers as u64).min(16);
    FilterConfig { variant: Variant::Sbf, block_bits: 256, k: k.max(4) / 4 * 4, log2_m_words, ..Default::default() }
        .validate()
}

fn main() -> anyhow::Result<()> {
    // two source organisms; four samples (two per organism)
    let reference = random_sequence(200_000, 7);
    let contaminant = random_sequence(200_000, 99);
    let sources = [&reference, &reference, &contaminant, &contaminant];

    // index each sample's read k-mers into its own namespace, building
    // all four filters with tickets in flight together
    let service = FilterService::new();
    let t0 = Instant::now();
    let mut handles: Vec<FilterHandle> = Vec::new();
    let mut build_tickets = Vec::new();
    let mut total_kmers = 0usize;
    for (i, source) in sources.iter().enumerate() {
        let reads = mutate_reads(source.as_slice(), READS_PER_SAMPLE, READ_LEN, 0.002, 8 + i as u64);
        let mut kmers = Vec::new();
        for read in &reads {
            extract_kmers(read, K, &mut kmers);
        }
        total_kmers += kmers.len();
        let name = format!("sample{i}");
        let handle = service.create_filter(&name, sample_config(kmers.len())?, 2)?;
        build_tickets.push(handle.add_bulk(&kmers));
        handles.push(handle);
    }
    for t in build_tickets {
        t.wait()?;
    }
    println!(
        "indexed {} samples ({total_kmers} k-mers total) in {:?}; catalog {:?}",
        sources.len(),
        t0.elapsed(),
        service.list_filters()
    );

    // markers: a slice of each organism's genome
    let mut ref_marker = Vec::new();
    extract_kmers(&reference[..5_000], K, &mut ref_marker);
    let mut contam_marker = Vec::new();
    extract_kmers(&contaminant[..5_000], K, &mut contam_marker);

    // screen both markers against every sample namespace concurrently
    let t1 = Instant::now();
    let screen = |marker: &[u64]| -> anyhow::Result<Vec<f64>> {
        let tickets: Vec<_> = handles.iter().map(|h| h.query_bulk(marker)).collect();
        let mut ratios = Vec::new();
        for t in tickets {
            let hits = t.wait()?;
            ratios.push(hits.iter().filter(|&&h| h).count() as f64 / marker.len() as f64);
        }
        Ok(ratios)
    };
    let ref_ratios = screen(&ref_marker)?;
    let contam_ratios = screen(&contam_marker)?;
    println!(
        "screened 2 markers x {} samples ({} lookups) in {:?}",
        handles.len(),
        2 * handles.len() * ref_marker.len().max(contam_marker.len()),
        t1.elapsed()
    );

    // presence/absence matrix
    println!("\nsample        ref-marker  contam-marker  call");
    for (i, (r, c)) in ref_ratios.iter().zip(&contam_ratios).enumerate() {
        let call = if r > c { "reference organism" } else { "contaminant organism" };
        println!("sample{i}       {r:>9.3}  {c:>12.3}  {call}");
    }
    for name in service.list_filters() {
        let stats = service.stats(&name)?;
        println!(
            "[{}] {} k-mers across {} shards, fill {:.1}%",
            stats.name,
            stats.metrics.adds,
            stats.num_shards,
            stats.shards.iter().map(|s| s.fill_ratio).sum::<f64>() / stats.shards.len().max(1) as f64 * 100.0
        );
    }

    // samples 0/1 carry the reference; 2/3 carry the contaminant
    for i in 0..2 {
        anyhow::ensure!(ref_ratios[i] > 0.5, "sample{i} should carry the reference marker");
        anyhow::ensure!(contam_ratios[i] < 0.1, "sample{i} should not carry the contaminant marker");
    }
    for i in 2..4 {
        anyhow::ensure!(contam_ratios[i] > 0.5, "sample{i} should carry the contaminant marker");
        anyhow::ensure!(ref_ratios[i] < 0.1, "sample{i} should not carry the reference marker");
    }
    println!("\nclassification OK: per-sample namespaces separate the organisms");
    Ok(())
}
