//! END-TO-END DRIVER: cluster mode — routed, replicated namespaces over
//! a fleet of wire servers.
//!
//! Boots three loopback wire servers, fronts them with a
//! `ClusterFilterService` (replication factor 2), and drives it through
//! the same transport-agnostic `FilterApi` every other caller uses:
//! namespaces are placed by rendezvous hashing, writes fan out to every
//! replica, reads route to the first live one. Mid-workload the demo
//! kills a replica and shows queries keep answering (bit-identical),
//! then rejoins it empty and shows `reconcile_now` re-seeding it by
//! snapshot shipping — the operator timeline of a node failure, on one
//! machine.
//!
//! Run:
//!     cargo run --release --example cluster_demo
//!     GBF_BENCH_QUICK=1 cargo run --release --example cluster_demo   # CI smoke
use std::net::TcpListener;
use std::sync::Arc;

use gbf::coordinator::{
    ClusterConfig, ClusterFilterService, FilterService, FilterSpec, GbfError, WireServer,
};
use gbf::filter::params::FilterConfig;
use gbf::workload::keygen::unique_keys;

/// `GBF_BENCH_QUICK=1` shrinks the workload for CI smoke runs.
fn keys_per_namespace() -> usize {
    if std::env::var("GBF_BENCH_QUICK").is_ok() {
        4_000
    } else {
        40_000
    }
}

fn spec(log2_m_words: u32, shards: usize) -> FilterSpec {
    FilterSpec::new(FilterConfig { log2_m_words, ..Default::default() }, shards)
}

fn boot_server(addr: &str) -> WireServer {
    WireServer::bind(Arc::new(FilterService::new()), addr).expect("binding wire server")
}

fn main() {
    // ---- fleet: three wire servers on loopback ----
    let mut servers: Vec<Option<WireServer>> =
        (0..3).map(|_| Some(boot_server("127.0.0.1:0"))).collect();
    let addrs: Vec<String> =
        servers.iter().map(|s| s.as_ref().unwrap().local_addr().to_string()).collect();
    println!("fleet: {addrs:?}");

    let sync_dir = std::env::temp_dir().join(format!("gbf-cluster-demo-{}", std::process::id()));
    let mut config = ClusterConfig::new(addrs, 2).expect("cluster config");
    config.sync_dir = sync_dir.to_string_lossy().into_owned();
    let cluster = ClusterFilterService::connect(config).expect("connecting cluster front end");

    // ---- placement: deterministic, visible, R=2 ----
    let namespaces = ["urls", "kmers", "edges"];
    for name in namespaces {
        println!("placement {name:>6} -> servers {:?}", cluster.config().placement(name));
    }

    // ---- populate through the one front end ----
    let n = keys_per_namespace();
    let mut probes = Vec::new();
    for (i, name) in namespaces.iter().enumerate() {
        let h = cluster.create_filter_spec(name, spec(16, 2)).expect("create");
        let keys = unique_keys(n, 0xD0 + i as u64);
        h.add_bulk(&keys).wait().expect("replicated add_bulk");
        let mut probe = keys;
        probe.extend(unique_keys(n / 2, 0xE0 + i as u64));
        let baseline = h.query_bulk(&probe).wait().expect("query_bulk");
        assert!(baseline[..n].iter().all(|&x| x), "no false negatives");
        probes.push((h, probe, baseline));
    }
    println!("populated {} namespaces x {n} keys (writes fanned out to 2 replicas each)", namespaces.len());

    // ---- kill one replica mid-workload ----
    let victim = cluster.config().placement("urls")[0];
    let victim_addr = servers[victim].as_ref().unwrap().local_addr().to_string();
    servers[victim] = None; // drop stops the listener and closes every connection
    println!("killed server {victim} ({victim_addr}) — the preferred replica for \"urls\"");

    for (h, probe, baseline) in &probes {
        let after = h.query_bulk(probe).wait().expect("failover query");
        assert_eq!(&after, baseline, "failover answers bit-identically for {}", h.name());
    }
    println!("all namespaces answer bit-identically through the surviving replicas");

    // writes keep acking while a replica is down (any-ack fan-out)
    probes[0].0.add(0xFEED).wait().expect("write with a replica down");

    // ---- rejoin empty, then re-seed by snapshot shipping ----
    servers[victim] = Some(boot_server(victim_addr.as_str()));
    println!("restarted server {victim} with an EMPTY catalog");
    cluster.reconcile_now();
    let stats = cluster.stats("urls").expect("stats after heal");
    println!(
        "reconciled: \"urls\" on the preferred replica again ({} adds, {} shards)",
        stats.metrics.adds, stats.num_shards
    );

    // ---- typed errors, not hangs, when the whole replica set is gone ----
    for s in servers.iter_mut() {
        *s = None;
    }
    match cluster.stats("urls") {
        Err(GbfError::NoQuorum { name, replicas }) => {
            println!("fleet gone: typed NoQuorum for {name:?} (all {replicas} replicas down)");
        }
        other => panic!("expected NoQuorum with the fleet down, got {other:?}"),
    }

    std::fs::remove_dir_all(&sync_dir).ok();
    println!("cluster_demo: OK");
}
