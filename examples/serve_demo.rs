//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Proves all layers compose: Pallas kernels (L1) lowered by JAX (L2) to
//! HLO artifacts, loaded by the PJRT runtime, driven by the Rust serving
//! coordinator (L3) under batched concurrent traffic — with the native
//! backend run side by side for comparison and cross-validation.
//!
//! Requires `make artifacts`. Run:
//!     cargo run --release --example serve_demo

use std::sync::Arc;
use std::time::{Duration, Instant};

use gbf::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, FilterBackend, NativeBackend, PjrtBackend};
use gbf::filter::params::FilterConfig;
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};
use gbf::workload::zipf::Zipf;

const N_CLIENTS: usize = 8;
const ADDS_PER_CLIENT: usize = 20_000;
const QUERIES_PER_CLIENT: usize = 30_000;

fn drive(coordinator: Arc<Coordinator>) -> anyhow::Result<()> {
    println!(
        "\n=== {} backend: {} shards, filter {} ===",
        coordinator.backend_name(),
        coordinator.num_shards(),
        coordinator.filter_config().name()
    );

    // Phase 1: concurrent clients ingest disjoint key ranges.
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..N_CLIENTS {
            let coordinator = Arc::clone(&coordinator);
            scope.spawn(move || {
                let keys = unique_keys(ADDS_PER_CLIENT, 0xADD + c as u64);
                coordinator.add_blocking(&keys).expect("add");
            });
        }
    });
    let ingest_dt = t0.elapsed();
    let total_adds = N_CLIENTS * ADDS_PER_CLIENT;
    println!(
        "ingest : {total_adds} adds in {ingest_dt:?} ({:.2} M ops/s)",
        total_adds as f64 / ingest_dt.as_secs_f64() / 1e6
    );

    // Phase 2: mixed lookup traffic — Zipf-skewed over the hot keys,
    // plus absent keys to exercise the negative path.
    let t1 = Instant::now();
    let mut client_results = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..N_CLIENTS {
            let coordinator = Arc::clone(&coordinator);
            handles.push(scope.spawn(move || {
                let hot = unique_keys(ADDS_PER_CLIENT, 0xADD + c as u64);
                let mut zipf = Zipf::new(hot.len() as u64, 1.2, c as u64);
                let trace = zipf.trace(&hot, QUERIES_PER_CLIENT / 2);
                let (_, absent) = disjoint_key_sets(1, QUERIES_PER_CLIENT / 2, 0xBAD + c as u64);
                let pos = coordinator.query_blocking(&trace).expect("query");
                let neg = coordinator.query_blocking(&absent).expect("query");
                let false_neg = pos.iter().filter(|&&h| !h).count();
                let false_pos = neg.iter().filter(|&&h| h).count();
                (false_neg, false_pos, neg.len())
            }));
        }
        for h in handles {
            client_results.push(h.join().unwrap());
        }
    });
    let query_dt = t1.elapsed();
    let total_queries = N_CLIENTS * QUERIES_PER_CLIENT;
    let false_negs: usize = client_results.iter().map(|r| r.0).sum();
    let false_pos: usize = client_results.iter().map(|r| r.1).sum();
    let negatives: usize = client_results.iter().map(|r| r.2).sum();
    println!(
        "lookup : {total_queries} queries in {query_dt:?} ({:.2} M ops/s)",
        total_queries as f64 / query_dt.as_secs_f64() / 1e6
    );
    println!(
        "quality: false negatives {false_negs} (MUST be 0), FPR {:.3e} over {negatives} absent keys",
        false_pos as f64 / negatives as f64
    );
    anyhow::ensure!(false_negs == 0, "false negatives through the serving stack!");
    println!("{}", coordinator.metrics().report());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let cfg = FilterConfig::default(); // matches the AOT artifacts (1 MiB)
    let policy = BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(300) };

    // --- native backend: the sharded registry (4 shards in parallel) ---
    let native = Coordinator::new(
        CoordinatorConfig { num_shards: 4, policy: policy.clone() },
        |num_shards| Ok(Box::new(NativeBackend::new(cfg, num_shards)?) as Box<dyn FilterBackend>),
    )?;
    drive(Arc::new(native))?;

    // --- PJRT backend: the AOT Pallas artifacts on the request path ---
    match Manifest::load(&default_artifact_dir()) {
        Ok(manifest) => {
            let actor = EngineActor::spawn_with_manifest(manifest.clone())?;
            let client = actor.client();
            // one filter state: PJRT shard placement is a ROADMAP item
            let pjrt = Coordinator::new(CoordinatorConfig { num_shards: 1, policy }, move |_| {
                Ok(Box::new(PjrtBackend::new(client.clone(), &manifest, cfg, "pallas")?)
                    as Box<dyn FilterBackend>)
            })?;
            drive(Arc::new(pjrt))?;
            println!("\nend-to-end OK: L1 Pallas -> L2 JAX -> HLO -> PJRT -> L3 coordinator");
        }
        Err(e) => {
            println!("\nskipping PJRT leg: {e:#} (run `make artifacts`)");
        }
    }
    Ok(())
}
