//! END-TO-END DRIVER: the multi-tenant filter service on a real workload.
//!
//! Proves all layers compose: a `FilterService` hosts several named
//! namespaces — different geometries, different shard counts — and serves
//! batched concurrent traffic to all of them at once through ticket-based
//! handles. When AOT artifacts are present, a PJRT-backed namespace joins
//! the same catalog (Pallas kernels (L1) lowered by JAX (L2) to HLO,
//! loaded by the PJRT runtime) and is cross-validated against a native
//! namespace serving identical traffic.
//!
//! Run:
//!     cargo run --release --example serve_demo

use std::time::{Duration, Instant};

use gbf::coordinator::{BatchPolicy, FilterBackend, FilterService, FilterSpec, PjrtBackend};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};
use gbf::workload::zipf::Zipf;

const CLIENTS_PER_TENANT: usize = 4;
const ADDS_PER_CLIENT: usize = 20_000;
const QUERIES_PER_CLIENT: usize = 30_000;

/// The tenant mix: one namespace per scenario, each with its own geometry.
fn tenant_specs() -> Vec<(&'static str, FilterConfig, usize)> {
    vec![
        ("ads-clicks", FilterConfig::default(), 4),
        ("search-cache", FilterConfig { variant: Variant::Bbf, log2_m_words: 16, ..Default::default() }, 2),
        ("fraud-keys", FilterConfig { variant: Variant::Cbf, log2_m_words: 15, ..Default::default() }, 1),
    ]
}

/// Drive one tenant with concurrent clients; returns (false_neg, false_pos,
/// negatives probed) aggregated over its clients.
fn drive_tenant(service: &FilterService, name: &str, seed: u64) -> anyhow::Result<(usize, usize, usize)> {
    let handle = service.handle(name)?;

    // ingest: concurrent clients, disjoint key ranges, pipelined tickets
    std::thread::scope(|scope| {
        for c in 0..CLIENTS_PER_TENANT {
            let handle = handle.clone();
            scope.spawn(move || {
                let keys = unique_keys(ADDS_PER_CLIENT, seed + c as u64);
                handle.add_bulk(&keys).wait().expect("add");
            });
        }
    });

    // lookup: Zipf-skewed hot traffic + absent keys, per client
    let mut totals = (0usize, 0usize, 0usize);
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..CLIENTS_PER_TENANT {
            let handle = handle.clone();
            joins.push(scope.spawn(move || {
                let hot = unique_keys(ADDS_PER_CLIENT, seed + c as u64);
                let mut zipf = Zipf::new(hot.len() as u64, 1.2, c as u64);
                let trace = zipf.trace(&hot, QUERIES_PER_CLIENT / 2);
                let (_, absent) = disjoint_key_sets(1, QUERIES_PER_CLIENT / 2, seed + 0xBAD + c as u64);
                // submit both tickets before waiting on either (async plane)
                let pos_ticket = handle.query_bulk(&trace);
                let neg_ticket = handle.query_bulk(&absent);
                let pos = pos_ticket.wait().expect("query");
                let neg = neg_ticket.wait().expect("query");
                let false_neg = pos.iter().filter(|&&h| !h).count();
                let false_pos = neg.iter().filter(|&&h| h).count();
                (false_neg, false_pos, neg.len())
            }));
        }
        for j in joins {
            let (fneg, fpos, n) = j.join().unwrap();
            totals.0 += fneg;
            totals.1 += fpos;
            totals.2 += n;
        }
    });
    Ok(totals)
}

fn main() -> anyhow::Result<()> {
    let service = FilterService::new();
    let policy = BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(300) };

    for (name, cfg, shards) in tenant_specs() {
        let spec = FilterSpec { config: cfg, shards, policy: policy.clone() };
        service.create_filter_spec(name, spec)?;
    }
    println!("catalog: {:?}", service.list_filters());

    // all tenants served concurrently — each has its own batcher + state,
    // so none serializes behind another
    let t0 = Instant::now();
    let mut outcomes = Vec::new();
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for (i, (name, _, _)) in tenant_specs().into_iter().enumerate() {
            let service = &service;
            joins.push(scope.spawn(move || (name, drive_tenant(service, name, 0xADD0 + i as u64 * 1000))));
        }
        for j in joins {
            outcomes.push(j.join().unwrap());
        }
    });
    let dt = t0.elapsed();

    let total_ops =
        tenant_specs().len() * CLIENTS_PER_TENANT * (ADDS_PER_CLIENT + QUERIES_PER_CLIENT);
    println!(
        "\ndrove {total_ops} ops across {} tenants in {dt:?} ({:.2} M ops/s aggregate)",
        tenant_specs().len(),
        total_ops as f64 / dt.as_secs_f64() / 1e6
    );
    for (name, outcome) in outcomes {
        let (false_neg, false_pos, negatives) = outcome?;
        println!(
            "[{name}] false negatives {false_neg} (MUST be 0), FPR {:.3e} over {negatives} absent keys",
            false_pos as f64 / negatives as f64
        );
        anyhow::ensure!(false_neg == 0, "false negatives in {name}!");
        let stats = service.stats(name)?;
        println!("{}", stats.report());
        anyhow::ensure!(
            stats.metrics.adds == (CLIENTS_PER_TENANT * ADDS_PER_CLIENT) as u64,
            "per-namespace counters count only their own tenant's traffic"
        );
    }

    // --- PJRT namespace: the AOT Pallas artifacts join the same catalog ---
    match Manifest::load(&default_artifact_dir()) {
        Ok(manifest) => {
            let cfg = FilterConfig::default(); // matches the AOT artifacts (1 MiB)
            let actor = EngineActor::spawn_with_manifest(manifest.clone())?;
            let client = actor.client();
            let spec = FilterSpec { config: cfg, shards: 1, policy };
            service.create_filter_with("pjrt-mirror", spec, move |_| {
                Ok(Box::new(PjrtBackend::new(client, &manifest, cfg, "pallas")?) as Box<dyn FilterBackend>)
            })?;
            // a native namespace with identical geometry serves as oracle:
            // same keys + same hash pipeline => bit-identical answers
            service.create_filter("native-mirror", cfg, 1)?;
            let pjrt = service.handle("pjrt-mirror")?;
            let native = service.handle("native-mirror")?;
            let keys = unique_keys(10_000, 0x90DD);
            let (_, probe) = disjoint_key_sets(1, 20_000, 0x90DE);
            let a = pjrt.add_bulk(&keys);
            let b = native.add_bulk(&keys);
            a.wait()?;
            b.wait()?;
            // same probe through both backends, tickets in flight together
            let p_ticket = pjrt.query_bulk(&probe);
            let n_ticket = native.query_bulk(&probe);
            anyhow::ensure!(p_ticket.wait()? == n_ticket.wait()?, "PJRT and native namespaces disagree");
            let inserted_hits = pjrt.query_bulk(&keys).wait()?;
            anyhow::ensure!(inserted_hits.iter().all(|&h| h), "false negative through PJRT namespace");
            println!("\n{}", service.stats("pjrt-mirror")?.report());
            println!("end-to-end OK: L1 Pallas -> L2 JAX -> HLO -> PJRT -> L3 FilterService namespace");
        }
        Err(e) => {
            println!("\nskipping PJRT namespace: {e:#} (run `make artifacts`)");
        }
    }
    Ok(())
}
