//! END-TO-END DRIVER: the multi-tenant filter service **over the wire**.
//!
//! Proves all layers compose across a socket: a `FilterService` is hosted
//! on a loopback `WireServer`, a `RemoteFilterService` connects to it,
//! and every tenant below is created and driven **remotely** through the
//! transport-agnostic `FilterApi` — the same trait an in-process caller
//! uses, with the same `Ticket` receipts and typed errors. Per-tenant
//! counters are then cross-checked against the server-side catalog to
//! show the two views of one namespace agree. When AOT artifacts are
//! present, a PJRT-backed namespace is created server-side (custom
//! backends are an in-process privilege) and served to the remote client
//! by name, cross-validated against a native twin on identical traffic.
//!
//! Run:
//!     cargo run --release --example serve_demo
//!     GBF_BENCH_QUICK=1 cargo run --release --example serve_demo   # CI smoke
//!
//! The catalog hosts several named namespaces — different geometries,
//! different shard counts — and serves batched concurrent traffic to all
//! of them at once through pipelined ticket-based handles.

use std::sync::Arc;
use std::time::{Duration, Instant};

use gbf::coordinator::{
    BatchPolicy, FilterApi, FilterBackend, FilterDataPlane, FilterService, FilterSpec, PjrtBackend,
    RemoteFilterService, WireServer,
};
use gbf::filter::params::{FilterConfig, Variant};
use gbf::runtime::actor::EngineActor;
use gbf::runtime::manifest::{default_artifact_dir, Manifest};
use gbf::workload::keygen::{disjoint_key_sets, unique_keys};
use gbf::workload::zipf::Zipf;

const CLIENTS_PER_TENANT: usize = 4;

/// `GBF_BENCH_QUICK=1` shrinks the workload for CI smoke runs.
fn quick() -> bool {
    std::env::var("GBF_BENCH_QUICK").is_ok()
}

fn adds_per_client() -> usize {
    if quick() {
        2_000
    } else {
        20_000
    }
}

fn queries_per_client() -> usize {
    if quick() {
        3_000
    } else {
        30_000
    }
}

/// The tenant mix: one namespace per scenario, each with its own geometry.
fn tenant_specs() -> Vec<(&'static str, FilterConfig, usize)> {
    vec![
        ("ads-clicks", FilterConfig::default(), 4),
        ("search-cache", FilterConfig { variant: Variant::Bbf, log2_m_words: 16, ..Default::default() }, 2),
        ("fraud-keys", FilterConfig { variant: Variant::Cbf, log2_m_words: 15, ..Default::default() }, 1),
    ]
}

/// Drive one tenant with concurrent clients through any `FilterApi`
/// transport; returns (false_neg, false_pos, negatives probed).
fn drive_tenant(api: &dyn FilterApi, name: &str, seed: u64) -> anyhow::Result<(usize, usize, usize)> {
    // one handle per tenant, cloned into each client thread (clone_box
    // is cheap on both transports — no per-thread admin round-trips)
    let tenant_handle: Box<dyn FilterDataPlane> = api.handle(name)?;

    // ingest: concurrent clients, disjoint key ranges
    std::thread::scope(|scope| {
        for c in 0..CLIENTS_PER_TENANT {
            let handle = tenant_handle.clone();
            scope.spawn(move || {
                let keys = unique_keys(adds_per_client(), seed + c as u64);
                handle.add_bulk(&keys).wait().expect("add");
            });
        }
    });

    // lookup: Zipf-skewed hot traffic + absent keys, per client
    let mut totals = (0usize, 0usize, 0usize);
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..CLIENTS_PER_TENANT {
            let handle = tenant_handle.clone();
            joins.push(scope.spawn(move || {
                let hot = unique_keys(adds_per_client(), seed + c as u64);
                let mut zipf = Zipf::new(hot.len() as u64, 1.2, c as u64);
                let trace = zipf.trace(&hot, queries_per_client() / 2);
                let (_, absent) = disjoint_key_sets(1, queries_per_client() / 2, seed + 0xBAD + c as u64);
                // submit both tickets before waiting on either: pipelined
                // request ids on the shared connection
                let pos_ticket = handle.query_bulk(&trace);
                let neg_ticket = handle.query_bulk(&absent);
                let pos = pos_ticket.wait().expect("query");
                let neg = neg_ticket.wait().expect("query");
                let false_neg = pos.iter().filter(|&&h| !h).count();
                let false_pos = neg.iter().filter(|&&h| h).count();
                (false_neg, false_pos, neg.len())
            }));
        }
        for j in joins {
            let (fneg, fpos, n) = j.join().unwrap();
            totals.0 += fneg;
            totals.1 += fpos;
            totals.2 += n;
        }
    });
    Ok(totals)
}

fn main() -> anyhow::Result<()> {
    // host the catalog on a loopback wire server; everything below goes
    // through the socket
    let service = Arc::new(FilterService::new());
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0")?;
    let client = RemoteFilterService::connect(server.local_addr())?;
    println!("wire server on {}, driving it remotely", server.local_addr());

    let policy = BatchPolicy { max_batch: 4096, max_wait: Duration::from_micros(300) };
    for (name, cfg, shards) in tenant_specs() {
        let spec = FilterSpec { config: cfg, shards, policy: policy.clone(), ..FilterSpec::default() };
        client.create_filter_spec(name, spec)?;
    }
    println!("remote catalog: {:?}", client.list_filters()?);

    // all tenants served concurrently — each has its own batcher + state
    // server-side, so none serializes behind another; the wire multiplexes
    // every client's requests over one pipelined connection
    let t0 = Instant::now();
    let mut outcomes = Vec::new();
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for (i, (name, _, _)) in tenant_specs().into_iter().enumerate() {
            let client = &client;
            joins.push(scope.spawn(move || (name, drive_tenant(client, name, 0xADD0 + i as u64 * 1000))));
        }
        for j in joins {
            outcomes.push(j.join().unwrap());
        }
    });
    let dt = t0.elapsed();

    let total_ops =
        tenant_specs().len() * CLIENTS_PER_TENANT * (adds_per_client() + queries_per_client());
    println!(
        "\ndrove {total_ops} ops over the wire across {} tenants in {dt:?} ({:.2} M ops/s aggregate)",
        tenant_specs().len(),
        total_ops as f64 / dt.as_secs_f64() / 1e6
    );
    for (name, outcome) in outcomes {
        let (false_neg, false_pos, negatives) = outcome?;
        println!(
            "[{name}] false negatives {false_neg} (MUST be 0), FPR {:.3e} over {negatives} absent keys",
            false_pos as f64 / negatives as f64
        );
        anyhow::ensure!(false_neg == 0, "false negatives in {name}!");
        // the remote stats view and the server-side catalog must agree
        let remote_stats = client.stats(name)?;
        let local_stats = service.stats(name)?;
        println!("{}", remote_stats.report());
        anyhow::ensure!(
            remote_stats.metrics.adds == (CLIENTS_PER_TENANT * adds_per_client()) as u64,
            "per-namespace counters count only their own tenant's traffic"
        );
        anyhow::ensure!(
            remote_stats.metrics.adds == local_stats.metrics.adds
                && remote_stats.metrics.queries == local_stats.metrics.queries
                && remote_stats.num_shards == local_stats.num_shards,
            "remote and in-process stats views of {name} disagree"
        );
    }

    // --- PJRT namespace: created server-side (custom backend), served
    // remotely by name ---
    match Manifest::load(&default_artifact_dir()) {
        Ok(manifest) => {
            let cfg = FilterConfig::default(); // matches the AOT artifacts (1 MiB)
            let actor = EngineActor::spawn_with_manifest(manifest.clone())?;
            let engine_client = actor.client();
            let spec = FilterSpec { config: cfg, shards: 1, policy, ..FilterSpec::default() };
            service.create_filter_with("pjrt-mirror", spec, move |_| {
                Ok(Box::new(PjrtBackend::new(engine_client, &manifest, cfg, "pallas")?)
                    as Box<dyn FilterBackend>)
            })?;
            // a native namespace with identical geometry serves as oracle:
            // same keys + same hash pipeline => bit-identical answers
            client.create_filter("native-mirror", cfg, 1)?;
            let pjrt = client.handle("pjrt-mirror")?;
            let native = client.handle("native-mirror")?;
            let keys = unique_keys(10_000, 0x90DD);
            let (_, probe) = disjoint_key_sets(1, 20_000, 0x90DE);
            let a = pjrt.add_bulk(&keys);
            let b = native.add_bulk(&keys);
            a.wait()?;
            b.wait()?;
            // same probe through both backends, tickets in flight together
            let p_ticket = pjrt.query_bulk(&probe);
            let n_ticket = native.query_bulk(&probe);
            anyhow::ensure!(p_ticket.wait()? == n_ticket.wait()?, "PJRT and native namespaces disagree");
            let inserted_hits = pjrt.query_bulk(&keys).wait()?;
            anyhow::ensure!(inserted_hits.iter().all(|&h| h), "false negative through PJRT namespace");
            println!("\n{}", client.stats("pjrt-mirror")?.report());
            println!("end-to-end OK: L1 Pallas -> L2 JAX -> HLO -> PJRT -> L3 FilterService -> wire");
        }
        Err(e) => {
            println!("\nskipping PJRT namespace: {e:#} (run `make artifacts`)");
        }
    }
    Ok(())
}
