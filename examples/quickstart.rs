//! Quickstart: the serving API in 10 lines, then the core filter library.
//!
//!     cargo run --release --example quickstart

use gbf::analytics::fpr::measure_fpr_space_optimal;
use gbf::coordinator::{FilterApi, FilterDataPlane, FilterService};
use gbf::filter::params::{space_optimal_n, FilterConfig};
use gbf::filter::sbf::Sbf;
use gbf::workload::keygen::disjoint_key_sets;

/// Written against `dyn FilterApi`, this runs unchanged on an in-process
/// `FilterService` (below) or a `RemoteFilterService` connected to a
/// `gbf serve --listen` wire server (see `serve_demo`).
fn count_present(api: &dyn FilterApi, keys: &[u64]) -> anyhow::Result<usize> {
    let scratch: Box<dyn FilterDataPlane> = api.create_filter("scratch", FilterConfig::default(), 2)?;
    scratch.add_bulk(keys).wait()?;
    let hits = scratch.query_bulk(keys).wait()?;
    api.drop_filter("scratch")?;
    Ok(hits.iter().filter(|&&h| h).count())
}

fn main() -> anyhow::Result<()> {
    // ---- FilterService hello-world: named filters, ticket receipts ----
    let service = FilterService::new();
    let users = service.create_filter("users", FilterConfig::default(), 4)?;
    users.add_bulk(&[101, 202, 303]).wait()?; // a Ticket: poll it, or .wait()
    let seen = users.query_bulk(&[101, 202, 303, 999]).wait()?;
    println!("service: namespaces {:?}, seen = {seen:?}", service.list_filters());
    assert_eq!(&seen[..3], &[true, true, true]); // no false negatives
    service.drop_filter("users")?; // admin plane: create / drop / list / stats

    // ---- one API, two transports ----
    // The same surface is a trait (`FilterApi` + `FilterDataPlane`), so
    // code like this is transport-agnostic: hand it a remote client and
    // it crosses the network instead.
    let present = count_present(&service, &[7, 8, 9])?;
    println!("FilterApi (transport-agnostic): {present}/3 inserted keys present");
    assert_eq!(present, 3);

    // ---- the filter library underneath ----
    // The paper's headline configuration: a Sectorized Bloom Filter with
    // 256-bit blocks of 64-bit words and k = 16 fingerprint bits.
    // 2^20 words = 8 MiB of filter (2^17 under GBF_BENCH_QUICK=1).
    let log2_m_words: u32 = if std::env::var("GBF_BENCH_QUICK").is_ok() { 17 } else { 20 };
    let filter = Sbf::headline(log2_m_words)?;
    let cfg = *filter.inner().config();
    println!("filter: {} ({} MiB)", cfg.name(), cfg.size_bytes() / (1024 * 1024));

    // Size the key set the way the paper does (§5.1): n = m ln2 / k.
    let n = space_optimal_n(cfg.m_bits(), cfg.k) as usize;
    let (keys, absent) = disjoint_key_sets(n, 100_000, 42);
    println!("inserting {n} keys (space-error-rate-optimal load)");

    // Bulk insert across all cores; lock-free atomic OR underneath.
    filter.bulk_add(&keys, 0);

    // No false negatives — ever. That is the Bloom filter contract.
    let hits = filter.bulk_contains(&keys, 0);
    assert!(hits.iter().all(|&h| h));
    println!("all {n} inserted keys found (no false negatives)");

    // False positives are bounded and measurable.
    let fp = filter.bulk_contains(&absent, 0).iter().filter(|&&h| h).count();
    println!("false positives: {fp}/100000 ({:.3e})", fp as f64 / 1e5);

    // Compare with theory (Eq. 1 and the blocked Poisson mixture).
    let report = measure_fpr_space_optimal(&cfg, 100_000, 1)?;
    println!(
        "theory: classic {:.3e}, blocked {:.3e}, measured {:.3e}",
        report.fpr_classic_theory, report.fpr_blocked_theory, report.fpr
    );

    // Single-key operations work too.
    filter.add(0xDEADBEEF);
    assert!(filter.contains(0xDEADBEEF));
    println!("single-key add/contains OK");

    // Every variant of Figure 1 is available behind the same engine:
    for cfg in [
        FilterConfig { variant: gbf::filter::Variant::Cbf, ..cfg },
        FilterConfig { variant: gbf::filter::Variant::Rbbf, block_bits: 64, ..cfg },
        FilterConfig { variant: gbf::filter::Variant::Csbf, block_bits: 512, z: 2, ..cfg },
    ] {
        let f = gbf::filter::AnyBloom::new(cfg.validate()?)?;
        f.bulk_add(&keys[..10_000], 0);
        let ok = f.bulk_contains(&keys[..10_000], 0).iter().all(|&h| h);
        println!("variant {:<26} no-false-negatives: {ok}", cfg.name());
    }
    Ok(())
}
