//! Database semi-join pre-filtering (paper §1, Gubner et al. / predicate
//! transfer) as a **multi-tenant service scenario**: a star-schema query
//! joins a fact table against *two* dimension tables, and each join gets
//! its own filter namespace on one `FilterService` — build both filters
//! through ticket-pipelined handles, screen the fact columns against both
//! namespaces, and only the doubly-surviving rows reach the hash joins.
//!
//!     cargo run --release --example join_prefilter

use std::collections::HashMap;
use std::time::Instant;

use gbf::coordinator::FilterService;
use gbf::filter::params::{FilterConfig, Variant};
use gbf::hash::splitmix64;
use gbf::workload::keygen::unique_keys;
use gbf::workload::zipf::Zipf;

fn main() -> anyhow::Result<()> {
    // dimension tables: 500k customers, 125k parts; fact table: 4M rows.
    // A fact row joins iff BOTH its customer and its part key match
    // (5% / 20% selectivity respectively).
    let customer_keys = unique_keys(500_000, 11);
    let part_keys = unique_keys(125_000, 13);
    let n_fact = 4_000_000usize;

    let mut state = 0xFac7_7ab1eu64;
    let mut cust_zipf = Zipf::new(customer_keys.len() as u64, 1.1, 3);
    let mut part_zipf = Zipf::new(part_keys.len() as u64, 1.1, 5);
    let mut fact_cust = Vec::with_capacity(n_fact);
    let mut fact_part = Vec::with_capacity(n_fact);
    for _ in 0..n_fact {
        let u = (splitmix64(&mut state) >> 40) as f64 / (1u64 << 24) as f64;
        if u <= 0.05 {
            fact_cust.push(customer_keys[(cust_zipf.sample() - 1) as usize]);
        } else {
            fact_cust.push(splitmix64(&mut state) | (1 << 63)); // disjoint range
        }
        let v = (splitmix64(&mut state) >> 40) as f64 / (1u64 << 24) as f64;
        if v <= 0.20 {
            fact_part.push(part_keys[(part_zipf.sample() - 1) as usize]);
        } else {
            fact_part.push(splitmix64(&mut state) | (1 << 63));
        }
    }

    // hash-join baseline: probe both HashMaps for every fact row
    let cust_ht: HashMap<u64, u32> =
        customer_keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
    let part_ht: HashMap<u64, u32> = part_keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
    let t0 = Instant::now();
    let mut joined_baseline = 0u64;
    for (&c, &p) in fact_cust.iter().zip(&fact_part) {
        if cust_ht.contains_key(&c) && part_ht.contains_key(&p) {
            joined_baseline += 1;
        }
    }
    let baseline_dt = t0.elapsed();

    // one namespace per join, sized to its dimension table (~16 bits/key)
    let service = FilterService::new();
    let dim_customer = service.create_filter(
        "dim_customer",
        FilterConfig { variant: Variant::Sbf, log2_m_words: 17, ..Default::default() }, // 1 MiB
        4,
    )?;
    let dim_part = service.create_filter(
        "dim_part",
        FilterConfig { variant: Variant::Sbf, log2_m_words: 15, ..Default::default() }, // 256 KiB
        2,
    )?;

    // build both filters with tickets in flight together
    let t1 = Instant::now();
    let build_c = dim_customer.add_bulk(&customer_keys);
    let build_p = dim_part.add_bulk(&part_keys);
    build_c.wait()?;
    build_p.wait()?;
    let build_dt = t1.elapsed();

    // screen both fact columns against their namespaces, again pipelined
    let t2 = Instant::now();
    let pass_c_ticket = dim_customer.query_bulk(&fact_cust);
    let pass_p_ticket = dim_part.query_bulk(&fact_part);
    let pass_c = pass_c_ticket.wait()?;
    let pass_p = pass_p_ticket.wait()?;
    let prefilter_dt = t2.elapsed();

    // residual: only doubly-surviving rows probe the hash tables
    let t3 = Instant::now();
    let mut joined_filtered = 0u64;
    let mut survivors = 0u64;
    for i in 0..n_fact {
        if pass_c[i] && pass_p[i] {
            survivors += 1;
            if cust_ht.contains_key(&fact_cust[i]) && part_ht.contains_key(&fact_part[i]) {
                joined_filtered += 1;
            }
        }
    }
    let probe_dt = t3.elapsed();

    assert_eq!(joined_baseline, joined_filtered, "the filters must never drop a match");
    let selectivity = survivors as f64 / n_fact as f64;
    let total_filtered = build_dt + prefilter_dt + probe_dt;

    println!("fact rows            : {n_fact}");
    println!(
        "true matches         : {joined_baseline} ({:.2}%)",
        100.0 * joined_baseline as f64 / n_fact as f64
    );
    println!("hash-join baseline   : {baseline_dt:?}");
    println!("filter builds        : {build_dt:?} (both namespaces in flight together)");
    println!(
        "bulk prefilter       : {prefilter_dt:?} ({:.1} M probes/s over both columns)",
        2.0 * n_fact as f64 / prefilter_dt.as_secs_f64() / 1e6
    );
    println!("survivors            : {survivors} ({:.2}% pass both screens)", selectivity * 100.0);
    println!("residual hash probes : {probe_dt:?}");
    println!(
        "filtered total       : {total_filtered:?} ({:.2}x vs baseline)",
        baseline_dt.as_secs_f64() / total_filtered.as_secs_f64()
    );
    for name in service.list_filters() {
        println!("{}", service.stats(&name)?.report());
    }
    // both screens together must cut the probe set hard: the AND of a 5%
    // and a 20% selectivity is ~1% + FPR slack
    anyhow::ensure!(selectivity < 0.05, "prefilter selectivity out of spec: {selectivity}");
    Ok(())
}
