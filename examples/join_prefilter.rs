//! Database semi-join pre-filtering (paper §1, Gubner et al. / predicate
//! transfer): build a Bloom filter over the dimension-table join keys and
//! use it to drop fact-table rows *before* the expensive join, comparing
//! probe cost with and without the filter.
//!
//!     cargo run --release --example join_prefilter

use std::collections::HashMap;
use std::time::Instant;

use gbf::filter::params::{FilterConfig, Variant};
use gbf::filter::AnyBloom;
use gbf::hash::splitmix64;
use gbf::workload::keygen::unique_keys;
use gbf::workload::zipf::Zipf;

fn main() -> anyhow::Result<()> {
    // dimension table: 1M keys; fact table: 20M rows, 5% of which match
    let dim_keys = unique_keys(1_000_000, 11);
    let n_fact = 20_000_000usize;
    let match_fraction = 0.05;

    let mut state = 0xFac7_7ab1eu64;
    let mut zipf = Zipf::new(dim_keys.len() as u64, 1.1, 3);
    let fact_keys: Vec<u64> = (0..n_fact)
        .map(|_| {
            if (splitmix64(&mut state) >> 40) as f64 / (1u64 << 24) as f64 <= match_fraction {
                // matching probe, skewed toward hot dimension rows
                dim_keys[(zipf.sample() - 1) as usize]
            } else {
                splitmix64(&mut state) | (1 << 63) // non-matching (disjoint range)
            }
        })
        .collect();

    // hash-join baseline: probe a HashMap for every fact row
    let ht: HashMap<u64, u32> = dim_keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
    let t0 = Instant::now();
    let mut joined_baseline = 0u64;
    for &k in &fact_keys {
        if ht.contains_key(&k) {
            joined_baseline += 1;
        }
    }
    let baseline_dt = t0.elapsed();

    // Bloom-prefiltered join: bulk-screen the fact column first
    let cfg = FilterConfig {
        variant: Variant::Sbf,
        block_bits: 256,
        k: 16,
        log2_m_words: 18, // 2 MiB filter = 16 bits/key for 1M keys
        ..Default::default()
    }
    .validate()?;
    let filter = AnyBloom::new(cfg)?;
    let t1 = Instant::now();
    filter.bulk_add(&dim_keys, 0);
    let build_dt = t1.elapsed();

    let t2 = Instant::now();
    let pass = filter.bulk_contains(&fact_keys, 0);
    let prefilter_dt = t2.elapsed();

    let t3 = Instant::now();
    let mut joined_filtered = 0u64;
    let mut survivors = 0u64;
    for (&k, &p) in fact_keys.iter().zip(&pass) {
        if p {
            survivors += 1;
            if ht.contains_key(&k) {
                joined_filtered += 1;
            }
        }
    }
    let probe_dt = t3.elapsed();

    assert_eq!(joined_baseline, joined_filtered, "the filter must never drop a match");
    let selectivity = survivors as f64 / n_fact as f64;
    let fpr = (survivors - joined_baseline) as f64 / (n_fact as u64 - joined_baseline) as f64;
    let total_filtered = build_dt + prefilter_dt + probe_dt;

    println!("fact rows            : {n_fact}");
    println!("true matches         : {joined_baseline} ({:.1}%)", 100.0 * joined_baseline as f64 / n_fact as f64);
    println!("hash-join baseline   : {baseline_dt:?}");
    println!("filter build         : {build_dt:?} ({})", cfg.name());
    println!(
        "bulk prefilter       : {prefilter_dt:?} ({:.1} M probes/s)",
        n_fact as f64 / prefilter_dt.as_secs_f64() / 1e6
    );
    println!("survivors            : {survivors} ({:.2}% pass, FPR {:.3e})", selectivity * 100.0, fpr);
    println!("residual hash probes : {probe_dt:?}");
    println!(
        "filtered total       : {total_filtered:?} ({:.2}x vs baseline)",
        baseline_dt.as_secs_f64() / total_filtered.as_secs_f64()
    );
    anyhow::ensure!(fpr < 5e-3, "FPR out of spec: {fpr}");
    Ok(())
}
