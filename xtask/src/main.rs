//! `cargo xtask` — repo automation for the correctness-tooling subsystem
//! (ISSUE 6 tentpole leg 4).
//!
//! Commands:
//!
//! * `cargo xtask lint`    — the custom static-analysis pass over the gbf
//!   hot paths (see [`lint`] for the rule table). Exits non-zero on any
//!   violation; CI runs it alongside clippy.
//! * `cargo xtask fuzz`    — replays the committed regression corpora
//!   (`rust/corpus/{wire,manifest}`) through the real decoders, then runs
//!   a bounded seeded mutation sweep. Exits non-zero on a panic, an
//!   unexpected decode failure of a `valid-*` entry, or a missing corpus.
//! * `cargo xtask locks`   — the lock-discipline passes (see [`locks`]):
//!   static lock-order over the classed-lock nesting graph,
//!   no-blocking-under-lock, and sync-shim-only.
//! * `cargo xtask lockgraph [--check]` — regenerate (or, with `--check`,
//!   verify) `LOCKS.md` and `rust/artifacts/lockgraph.dot` from the
//!   static graph merged with the runtime lockdep witness's observations.
//! * `cargo xtask analyze` — all of the above, in order. The CI analysis
//!   job.
//!
//! The lint is a deliberately simple line scanner, not a rustc driver: the
//! offline toolchain has no rustc plugin API available, and the rules are
//! all lexical. Known limits (acceptable for the rule set): brace counting
//! inside `#[cfg(test)]` regions assumes string literals keep braces
//! balanced, which holds for format strings and everything in-tree.

use std::fmt::Write as _;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{bail, Context, Result};
use gbf::coordinator::persist::SnapshotManifest;
use gbf::coordinator::wire::codec::{decode_request, decode_response, read_frame};
use gbf::infra::fuzz::{load_corpus, Mutator};

mod lexer;
mod locks;

fn main() -> ExitCode {
    let command = std::env::args().nth(1).unwrap_or_default();
    let outcome = match command.as_str() {
        "lint" => lint(),
        "locks" => locks::locks(),
        "fuzz" => fuzz(),
        "lockgraph" => locks::lockgraph(std::env::args().nth(2).as_deref() == Some("--check")),
        "analyze" => lint()
            .and_then(|()| locks::locks())
            .and_then(|()| locks::lockgraph(true))
            .and_then(|()| fuzz()),
        other => {
            eprintln!("unknown command {other:?}\n\nusage: cargo xtask <lint|locks|fuzz|lockgraph [--check]|analyze>");
            return ExitCode::FAILURE;
        }
    };
    match outcome {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Workspace root, resolved from this crate's manifest so the commands
/// work from any working directory.
pub(crate) fn repo_root() -> PathBuf {
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest_dir.parent().expect("xtask lives one level under the workspace root").to_path_buf()
}

// ---- lint ----

/// One rule violation, formatted `path:line: message`.
#[derive(Debug)]
pub(crate) struct Violation {
    pub(crate) file: PathBuf,
    pub(crate) line: usize,
    pub(crate) message: String,
}

/// The static-analysis pass. Rule table (all rules skip `#[cfg(test)]`
/// regions and comment lines):
///
/// | scope                                      | rule                                             |
/// |--------------------------------------------|--------------------------------------------------|
/// | `coordinator/wire/`, `coordinator/server.rs` | no `.unwrap()` / `.expect(` — the wire path must surface typed errors |
/// | `filter/`                                  | no `get_unchecked` — kernel loops stay bounds-checked (the optimizer hoists the checks) |
/// | everywhere                                 | every `unsafe` needs an adjacent `// SAFETY:` comment |
/// | everywhere                                 | every `Ordering::` choice needs a justifying comment within 10 lines |
/// | outside [`FAILPOINT_FILES`]                | no `fail_point!` / `fail_torn!` — failpoints live only in the instrumented modules catalogued in DESIGN.md |
/// | `infra/fault.rs`                           | `mod imp` and `pub use imp::*` must sit under `#[cfg(failpoints)]` — failpoints-off builds carry no registry code |
fn lint() -> Result<()> {
    let src = repo_root().join("rust").join("src");
    let violations = lint_tree(&src)?;
    if violations.is_empty() {
        println!("xtask lint: clean");
        return Ok(());
    }
    let mut report = String::new();
    for v in &violations {
        let _ = writeln!(report, "{}:{}: {}", v.file.display(), v.line, v.message);
    }
    bail!("xtask lint: {} violation(s)\n{report}", violations.len());
}

fn lint_tree(src: &Path) -> Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(src, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    for file in files {
        let text = std::fs::read_to_string(&file).with_context(|| format!("reading {}", file.display()))?;
        let rel = file.strip_prefix(src).unwrap_or(&file).to_string_lossy().replace('\\', "/");
        lint_file(&file, &rel, &text, &mut violations);
    }
    Ok(violations)
}

pub(crate) fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Mark every line belonging to a `#[cfg(test)]`-gated item (including
/// `#[cfg(all(test, loom))]` and friends) by brace counting from the
/// attribute to the close of the item it gates.
pub(crate) fn test_region_mask(lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let t = lines[i].trim_start();
        let gates_test = t.starts_with("#[cfg(") && t.contains("test") && !t.contains("not(test)");
        if !gates_test {
            i += 1;
            continue;
        }
        let mut depth = 0usize;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            mask[j] = true;
            for ch in lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth = depth.saturating_sub(1),
                    _ => {}
                }
            }
            if opened && depth == 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

fn is_attr_or_blank(line: &str) -> bool {
    let t = line.trim();
    t.is_empty() || t.starts_with("#[") || t.starts_with("#![")
}

/// True when `line` contains `word` as a standalone token (not a prefix of
/// a longer identifier like `unsafe_code`).
fn has_word(line: &str, word: &str) -> bool {
    let mut rest = line;
    while let Some(at) = rest.find(word) {
        let before_ok = at == 0 || !is_ident_char(rest.as_bytes()[at - 1]);
        let after = at + word.len();
        let after_ok = after >= rest.len() || !is_ident_char(rest.as_bytes()[after]);
        if before_ok && after_ok {
            return true;
        }
        rest = &rest[after..];
    }
    false
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// The instrumented-module allowlist (ISSUE 10): every `fail_point!` /
/// `fail_torn!` site lives in one of these files, mirroring the
/// failpoint catalog in DESIGN.md. A failpoint anywhere else widens the
/// chaos surface silently — add the point to the catalog (and the chaos
/// suite) first, then extend this list.
const FAILPOINT_FILES: [&str; 6] = [
    "coordinator/batcher.rs",
    "coordinator/cluster/mod.rs",
    "coordinator/persist/mod.rs",
    "coordinator/wire/client.rs",
    "coordinator/wire/server.rs",
    "infra/fault.rs",
];

fn lint_file(file: &Path, rel: &str, text: &str, violations: &mut Vec<Violation>) {
    let lines: Vec<&str> = text.lines().collect();
    let in_test = test_region_mask(&lines);

    let wire_scope = rel.starts_with("coordinator/wire/") || rel == "coordinator/server.rs";
    let filter_scope = rel.starts_with("filter/");
    let failpoint_scope = FAILPOINT_FILES.contains(&rel);

    for (idx, &line) in lines.iter().enumerate() {
        if in_test[idx] || is_comment(line) {
            continue;
        }
        let lineno = idx + 1;
        // Strip a trailing line comment so justifications don't trigger
        // code rules; crude (ignores `//` inside strings) but the tree
        // has no such strings on rule-relevant lines.
        let code = line.split("//").next().unwrap_or(line);

        if wire_scope && (code.contains(".unwrap()") || code.contains(".expect(")) {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                message: "unwrap/expect on the wire path — return a typed GbfError instead".into(),
            });
        }

        if filter_scope && code.contains("get_unchecked") {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                message: "unchecked indexing in a filter kernel — keep bounds checks (the optimizer hoists them)"
                    .into(),
            });
        }

        if has_word(code, "unsafe") && !safety_comment_above(&lines, idx) {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                message: "unsafe without an adjacent `// SAFETY:` comment".into(),
            });
        }

        if code.contains("Ordering::") && !ordering_justified(&lines, idx) {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                message: "memory-ordering choice without a justifying comment within 10 lines".into(),
            });
        }

        if !failpoint_scope && (code.contains("fail_point!") || code.contains("fail_torn!")) {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                message: "failpoint outside the instrumented-module allowlist — add the point to \
                          DESIGN.md's catalog (and FAILPOINT_FILES) first"
                    .into(),
            });
        }

        // the zero-cost claim: without `--cfg failpoints` the registry
        // is not compiled at all, so the module body and its re-export
        // must each sit directly under the cfg gate
        if rel == "infra/fault.rs"
            && (code.trim_start().starts_with("mod imp")
                || code.trim_start().starts_with("pub use imp::"))
            && !(idx > 0 && lines[idx - 1].contains("#[cfg(failpoints)]"))
        {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                message: "fault registry internals must be `#[cfg(failpoints)]`-gated — \
                          failpoints-off builds carry no registry code"
                    .into(),
            });
        }
    }
}

/// Walk upward over comments, attributes, and blank lines looking for a
/// `SAFETY:` comment attached to the `unsafe` at `idx`.
fn safety_comment_above(lines: &[&str], idx: usize) -> bool {
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let line = lines[k];
        if is_comment(line) {
            if line.contains("SAFETY:") {
                return true;
            }
        } else if !is_attr_or_blank(line) {
            return false;
        }
    }
    false
}

/// A justifying comment for an `Ordering::` choice: a comment line within
/// the previous 10 lines (or trailing on the same line) naming the
/// ordering or its pairing.
fn ordering_justified(lines: &[&str], idx: usize) -> bool {
    const KEYWORDS: [&str; 7] = ["Ordering", "Relaxed", "Acquire", "Release", "SeqCst", "AcqRel", "pairs with"];
    let trailing = lines[idx].split_once("//").map(|(_, c)| c).unwrap_or("");
    if KEYWORDS.iter().any(|k| trailing.contains(k)) {
        return true;
    }
    for back in 1..=10 {
        let Some(k) = idx.checked_sub(back) else { break };
        let line = lines[k];
        if is_comment(line) && KEYWORDS.iter().any(|kw| line.contains(kw)) {
            return true;
        }
    }
    false
}

// ---- fuzz ----

/// Replay the committed corpora through the real decoders, then run a
/// bounded seeded mutation sweep. Mirrors the `codec_fuzz` /
/// `manifest_fuzz` integration tests so a violation fails CI from either
/// entry point.
fn fuzz() -> Result<()> {
    let root = repo_root();
    let mut failures = Vec::new();

    let wire = load_corpus(&root.join("rust").join("corpus").join("wire")).map_err(anyhow::Error::msg)?;
    if wire.is_empty() {
        bail!("wire corpus is empty");
    }
    for (path, bytes) in &wire {
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if name.starts_with("frame-") {
                read_frame(&mut &bytes[..]).is_ok()
            } else if name.starts_with("resp-") {
                decode_response(bytes).is_ok()
            } else {
                decode_request(bytes).is_ok()
            }
        }));
        match outcome {
            Err(_) => failures.push(format!("{name}: decoder panicked")),
            Ok(accepted) => {
                let must_accept = name.starts_with("valid-") || name.starts_with("resp-valid-");
                if must_accept && !accepted {
                    failures.push(format!("{name}: pinned valid encoding no longer decodes"));
                }
                if !must_accept && accepted && name.contains('-') && is_hostile(&name) {
                    failures.push(format!("{name}: pinned hostile encoding decoded successfully"));
                }
            }
        }
    }

    let manifest = load_corpus(&root.join("rust").join("corpus").join("manifest")).map_err(anyhow::Error::msg)?;
    if manifest.is_empty() {
        bail!("manifest corpus is empty");
    }
    for (path, bytes) in &manifest {
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        let text = String::from_utf8_lossy(bytes).into_owned();
        match catch_unwind(AssertUnwindSafe(|| SnapshotManifest::from_json_str(&text).is_ok())) {
            Err(_) => failures.push(format!("{name}: manifest parser panicked")),
            Ok(accepted) => {
                if name.starts_with("valid") && !accepted {
                    failures.push(format!("{name}: pinned valid manifest no longer parses"));
                }
                if !name.starts_with("valid") && accepted {
                    failures.push(format!("{name}: pinned hostile manifest parsed successfully"));
                }
            }
        }
    }

    // Bounded fresh sweep: deterministic seed so CI failures replay
    // locally byte for byte (`GBF_FUZZ_SEED` widens the hunt).
    let seed = std::env::var("GBF_FUZZ_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x00C0_FFEEu64);
    let iters: u64 = std::env::var("GBF_FUZZ_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(500);
    let wire_valid: Vec<&Vec<u8>> = wire
        .iter()
        .filter(|(p, _)| p.file_name().is_some_and(|n| n.to_string_lossy().starts_with("valid-")))
        .map(|(_, b)| b)
        .collect();
    let mut mutator = Mutator::new(seed);
    for i in 0..iters {
        let a = wire_valid[(i % wire_valid.len() as u64) as usize];
        let b = wire_valid[((i / 3) % wire_valid.len() as u64) as usize];
        let mutant = mutator.mutate(a, b);
        if catch_unwind(AssertUnwindSafe(|| decode_request(&mutant).map(|_| ()))).is_err() {
            failures.push(format!("mutation sweep: decode_request panicked (seed {seed}, iter {i})"));
        }
    }
    let manifest_valid = &manifest
        .iter()
        .find(|(p, _)| p.file_name().is_some_and(|n| n == "valid.json"))
        .expect("valid.json in corpus")
        .1;
    for i in 0..iters {
        let mutant = mutator.mutate(manifest_valid, manifest_valid);
        let text = String::from_utf8_lossy(&mutant).into_owned();
        if catch_unwind(AssertUnwindSafe(|| SnapshotManifest::from_json_str(&text).map(|_| ()))).is_err() {
            failures.push(format!("mutation sweep: manifest parser panicked (seed {seed}, iter {i})"));
        }
    }

    if failures.is_empty() {
        println!(
            "xtask fuzz: {} wire + {} manifest corpus entries replayed, {iters}+{iters} mutants swept (seed {seed})",
            wire.len(),
            manifest.len()
        );
        return Ok(());
    }
    bail!("xtask fuzz: {} failure(s)\n{}", failures.len(), failures.join("\n"));
}

/// Hostile wire-corpus entries that must NOT decode. `create-max-batch-zero`
/// deliberately decodes (the codec is transparent; the service refuses it),
/// so it is replay-only.
fn is_hostile(name: &str) -> bool {
    [
        "truncated-",
        "trailing-",
        "unknown-",
        "bad-",
        "keys-length-lie",
        "resp-names-count-lie",
        "resp-err-truncated",
        "resp-deadline-truncated",
        "snapshot-name-oversize",
        "ping-trailing-garbage",
    ]
    .iter()
    .any(|p| name.starts_with(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The committed tree must satisfy its own lint — this is the unit-test
    /// mirror of the CI `cargo xtask analyze` gate.
    #[test]
    fn repo_is_lint_clean() {
        let src = repo_root().join("rust").join("src");
        let violations = lint_tree(&src).expect("lint pass runs");
        let report: Vec<String> =
            violations.iter().map(|v| format!("{}:{}: {}", v.file.display(), v.line, v.message)).collect();
        assert!(violations.is_empty(), "lint violations:\n{}", report.join("\n"));
    }

    #[test]
    fn lint_catches_each_rule() {
        let dir = std::env::temp_dir().join(format!("gbf-xtask-lint-{}", std::process::id()));
        let wire = dir.join("coordinator").join("wire");
        let filter = dir.join("filter");
        std::fs::create_dir_all(&wire).expect("mkdir");
        std::fs::create_dir_all(&filter).expect("mkdir");
        std::fs::write(
            wire.join("bad.rs"),
            "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n",
        )
        .expect("write");
        std::fs::write(
            filter.join("bad.rs"),
            "fn g(v: &[u8]) -> u8 {\n    unsafe { *v.get_unchecked(0) }\n}\n\
             fn h(a: &std::sync::atomic::AtomicU64) -> u64 {\n    a.load(std::sync::atomic::Ordering::Relaxed)\n}\n",
        )
        .expect("write");
        // Test regions are exempt from every rule — even inside the
        // unwrap-banned wire scope.
        std::fs::write(
            wire.join("tested.rs"),
            "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n",
        )
        .expect("write");
        // failpoints outside the instrumented allowlist are rejected
        std::fs::write(
            filter.join("chaotic.rs"),
            "fn f() {\n    fail_point!(\"filter.rogue\");\n}\n",
        )
        .expect("write");
        // the fault registry's internals must carry the cfg gate
        let infra = dir.join("infra");
        std::fs::create_dir_all(&infra).expect("mkdir");
        std::fs::write(infra.join("fault.rs"), "mod imp {\n}\npub use imp::*;\n").expect("write");
        let violations = lint_tree(&dir).expect("lint runs");
        let messages: Vec<&str> = violations.iter().map(|v| v.message.as_str()).collect();
        assert!(messages.iter().any(|m| m.contains("unwrap/expect")), "{messages:?}");
        assert!(messages.iter().any(|m| m.contains("unchecked indexing")), "{messages:?}");
        assert!(messages.iter().any(|m| m.contains("SAFETY")), "{messages:?}");
        assert!(messages.iter().any(|m| m.contains("memory-ordering")), "{messages:?}");
        assert!(messages.iter().any(|m| m.contains("instrumented-module allowlist")), "{messages:?}");
        assert!(messages.iter().any(|m| m.contains("cfg(failpoints)")), "{messages:?}");
        assert!(
            violations.iter().all(|v| !v.file.ends_with("tested.rs")),
            "test regions must be exempt: {violations:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn word_boundaries_respected() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(has_word("pub unsafe fn x()", "unsafe"));
        assert!(!has_word("#![forbid(unsafe_code)]", "unsafe"));
        assert!(!has_word("let unsafely = 1;", "unsafe"));
    }
}
