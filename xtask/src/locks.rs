//! Lock-discipline static passes and the merged lock-order graph
//! (ISSUE 7 tentpole, static half — the runtime half is the lockdep
//! witness in `gbf::infra::lockdep`).
//!
//! Three rules over the token stream of `rust/src` (test regions and the
//! witness/model plumbing itself excluded):
//!
//! | rule                    | what it enforces                                               |
//! |-------------------------|----------------------------------------------------------------|
//! | `lock-order`            | the static class-nesting graph (plus one level of call composition) is acyclic and never contradicts a documented `LOCKS.md` edge |
//! | `no-blocking-under-lock`| no blocking call (condvar wait on a foreign guard, frame or file I/O, `recv`, `join`, `sleep`) while a classed guard is held, outside a small audited allowlist |
//! | `sync-shim-only`        | no direct `std::sync::{Mutex, Condvar, RwLock, atomic}` outside `infra/` — classed shim locks are what feed the witness |
//!
//! The analyzer is a scope walk over the `lexer` token stream, not a
//! rustc driver (the offline toolchain has no plugin API). The guard
//! model is deliberately simple and documented here because `LOCKS.md`
//! is generated from it:
//!
//! * A lock class is born at `Mutex::new_class("name", ..)` (likewise
//!   `RwLock`/`Condvar`); the binding it is assigned to — `let` binding
//!   or struct-literal field — resolves receivers of later acquisitions.
//!   Locks built with the bare constructors stay anonymous and invisible,
//!   matching the runtime witness exactly.
//! * `x.lock()` / zero-arg `x.read()` / `x.write()` /
//!   `lock_unpoisoned(&x)` acquire the class `x` resolves to (an `xs[i]`
//!   receiver resolves through `xs`; a singular `lane` falls back to the
//!   plural field `lanes`). Unresolvable receivers are anonymous.
//! * A guard is *let-bound* (held to the end of its block) only when the
//!   acquisition is chained through nothing but `unwrap`/`expect`/
//!   `unwrap_or_else` into a `let` with no `match`/`while`/`for`/`loop`
//!   between statement start and the acquisition; anything else —
//!   arguments, further method calls, `match` scrutinees — is
//!   statement-scoped and released at the next `;`, `{`, or `}`.
//!   `drop(guard)` releases early.
//! * Acquiring class B with class A held folds the edge `A -> B` with
//!   both sites. Calling a function that is defined exactly once in the
//!   tree composes that callee's direct acquisitions one level deep.
//!
//! `cargo xtask lockgraph` regenerates `LOCKS.md` and
//! `rust/artifacts/lockgraph.dot` from the union of this static graph
//! and the runtime witness's observations over a representative
//! workload; `--check` is the CI freshness gate.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::lexer::{lex, Tok, Token};
use crate::{collect_rs_files, repo_root, test_region_mask, Violation};

/// Files whose raw `std::sync` / nesting is the *implementation* of the
/// discipline, not a subject of it.
const EXCLUDED_FILES: &[&str] = &["infra/sync.rs", "infra/check.rs", "infra/lockdep.rs"];

/// (file, class) pairs audited as safe to block while held:
/// the wire writer mutexes exist to serialize `write_frame`, and
/// `ConnRegistry::reap` only joins handler threads that are already
/// finished.
const BLOCKING_ALLOWLIST: &[(&str, &str)] = &[
    ("coordinator/wire/client.rs", "wire.client.writer"),
    ("coordinator/wire/server.rs", "wire.server.conns"),
    ("coordinator/wire/server.rs", "wire.server.writer"),
];

/// `filter/bloom.rs` drives `AtomicU32` word CAS loops and fences the
/// shim does not model; everything else goes through `infra::sync`.
const SYNC_SHIM_ALLOWLIST: &[&str] = &["filter/bloom.rs"];

/// Callee names never composed: shared with std/container methods, so a
/// `map.insert(..)` under a guard must not pick up an unrelated in-tree
/// `fn insert`'s acquisitions.
const COMPOSE_BLOCKLIST: &[&str] = &[
    "and_then", "clone", "cloned", "collect", "contains_key", "drain", "drop", "entry", "expect", "extend",
    "fetch_add", "filter", "format", "get", "insert", "is_empty", "iter", "join", "len", "load", "lock", "map",
    "map_err", "next", "ok_or_else", "pop", "pop_front", "push", "push_back", "read", "recv", "remove",
    "retain", "send", "set", "store", "take", "to_string", "unwrap", "unwrap_or_else", "wait", "write",
];

/// Method/function names that block the calling thread. `send` is absent
/// on purpose: the only sends under a guard are unbounded-mpsc sends,
/// which never block.
const BLOCKING_CALLS: &[&str] = &[
    "copy", "create_dir_all", "join", "read_frame", "read_to_string", "recv", "recv_timeout", "remove_dir_all",
    "rename", "sleep", "sync_all", "write_frame",
];

const WAIT_CALLS: &[&str] = &["wait", "wait_timeout", "wait_while"];

/// One lock class declaration (`T::new_class("name", ..)`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClassDecl {
    pub class: String,
    /// "mutex" | "rwlock" | "condvar"
    pub kind: &'static str,
    /// Path relative to `rust/src`, `/`-separated.
    pub file: String,
}

/// A folded `from -> to` ("held while acquiring") edge with one witness
/// site per endpoint (first sighting wins, matching the runtime witness).
#[derive(Debug, Clone)]
pub struct EdgeInfo {
    pub from_file: String,
    pub from_line: usize,
    pub to_file: String,
    pub to_line: usize,
}

impl EdgeInfo {
    fn from_site(&self) -> String {
        format!("{}:{}", self.from_file, self.from_line)
    }
    fn to_site(&self) -> String {
        format!("{}:{}", self.to_file, self.to_line)
    }
}

pub struct Analysis {
    pub classes: Vec<ClassDecl>,
    pub edges: BTreeMap<(String, String), EdgeInfo>,
    pub violations: Vec<Violation>,
}

// ---- per-function facts (for one-level call composition) ----

struct FnSummary {
    file: String,
    /// Classes this function acquires directly: (class, line).
    acquires: Vec<(String, usize)>,
    /// Calls made with classed guards held: (held snapshot, callee).
    calls_under_lock: Vec<(Vec<(String, usize)>, String)>,
}

enum FnDef {
    Unique(usize),
    Ambiguous,
}

struct Hold {
    class: String,
    line: usize,
    /// `None` = statement-scoped temporary.
    binding: Option<String>,
    /// Block depth at acquisition; a let-bound guard dies when depth
    /// drops below this.
    depth: usize,
}

/// Run all three rules over `src` and fold the static class graph.
pub fn analyze_tree(src: &Path) -> Result<Analysis> {
    let mut files = Vec::new();
    collect_rs_files(src, &mut files)?;
    files.sort();

    let mut classes: Vec<ClassDecl> = Vec::new();
    let mut edges: BTreeMap<(String, String), EdgeInfo> = BTreeMap::new();
    let mut violations: Vec<Violation> = Vec::new();
    let mut fn_defs: BTreeMap<String, FnDef> = BTreeMap::new();
    let mut summaries: Vec<FnSummary> = Vec::new();

    for file in &files {
        let rel = file.strip_prefix(src).unwrap_or(file).to_string_lossy().replace('\\', "/");
        if EXCLUDED_FILES.contains(&rel.as_str()) {
            continue;
        }
        let text = std::fs::read_to_string(file).with_context(|| format!("reading {}", file.display()))?;
        let lines: Vec<&str> = text.lines().collect();
        let mask = test_region_mask(&lines);
        let toks: Vec<Token> =
            lex(&text).into_iter().filter(|t| !mask.get(t.line - 1).copied().unwrap_or(false)).collect();

        sync_shim_rule(file, &rel, &toks, &mut violations);
        let table = class_table(&rel, &toks, &mut classes);
        scan_functions(file, &rel, &toks, &table, &mut edges, &mut violations, &mut fn_defs, &mut summaries);
    }

    compose_calls(&fn_defs, &summaries, &mut edges);
    cycle_check(&edges, &mut violations);

    classes.sort_by(|a, b| a.class.cmp(&b.class).then_with(|| a.file.cmp(&b.file)));
    classes.dedup();
    Ok(Analysis { classes, edges, violations })
}

// ---- rule: sync-shim-only ----

fn sync_shim_rule(file: &Path, rel: &str, toks: &[Token], violations: &mut Vec<Violation>) {
    if rel.starts_with("infra/") || SYNC_SHIM_ALLOWLIST.contains(&rel) {
        return;
    }
    let banned = |name: &str| matches!(name, "Mutex" | "Condvar" | "RwLock" | "atomic");
    let mut flag = |line: usize, name: &str, violations: &mut Vec<Violation>| {
        violations.push(Violation {
            file: file.to_path_buf(),
            line,
            message: format!(
                "direct std::sync::{name} outside infra/ — use the infra::sync shim so the lock is classed for the lockdep witness"
            ),
        });
    };
    let mut i = 0;
    while i + 4 < toks.len() {
        let path = toks[i].is_ident("std")
            && toks[i + 1].is_punct(':')
            && toks[i + 2].is_punct(':')
            && toks[i + 3].is_ident("sync")
            && toks[i + 4].is_punct(':');
        if !path {
            i += 1;
            continue;
        }
        // std :: sync :: <next>
        let mut j = i + 5;
        while j < toks.len() && toks[j].is_punct(':') {
            j += 1;
        }
        match toks.get(j).map(|t| &t.tok) {
            Some(Tok::Ident(name)) if banned(name) => flag(toks[j].line, name, violations),
            Some(Tok::Punct('{')) => {
                // grouped import: scan idents to the matching close brace
                let mut depth = 1;
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    match &toks[k].tok {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => depth -= 1,
                        Tok::Ident(name) if banned(name) => flag(toks[k].line, name, violations),
                        _ => {}
                    }
                    k += 1;
                }
            }
            _ => {}
        }
        i = j;
    }
}

// ---- class declarations ----

/// Extract `T::new_class("name", ..)` declarations: the inventory entry
/// plus the binding (`let` name or struct-literal field) later
/// acquisitions resolve through. Bindings that would be ambiguous within
/// a file are dropped rather than guessed.
fn class_table(rel: &str, toks: &[Token], classes: &mut Vec<ClassDecl>) -> HashMap<String, String> {
    let mut table: HashMap<String, Option<String>> = HashMap::new();
    for i in 0..toks.len() {
        if !toks[i].is_ident("new_class") {
            continue;
        }
        if i < 3 || !toks[i - 1].is_punct(':') || !toks[i - 2].is_punct(':') {
            continue;
        }
        let Some(ty) = toks[i - 3].ident() else { continue };
        let kind = match ty {
            "Mutex" => "mutex",
            "RwLock" => "rwlock",
            "Condvar" => "condvar",
            _ => continue,
        };
        let Some(class) = toks.get(i + 2).and_then(|t| t.str_lit()) else { continue };
        classes.push(ClassDecl { class: class.to_string(), kind, file: rel.to_string() });
        if kind == "condvar" {
            continue; // condvars are wait targets, not lock receivers
        }
        if let Some(binding) = binding_for_decl(toks, i - 3) {
            match table.get(&binding) {
                Some(Some(existing)) if existing != class => {
                    table.insert(binding, None); // ambiguous: never resolve it
                }
                Some(None) => {}
                _ => {
                    table.insert(binding, Some(class.to_string()));
                }
            }
        }
    }
    table.into_iter().filter_map(|(k, v)| v.map(|c| (k, c))).collect()
}

/// The binding a declaration at token `decl` (the type ident) lands in:
/// scan back to the statement/field boundary; a window with `let` binds
/// the first pattern ident, otherwise the nearest `field:` wins.
fn binding_for_decl(toks: &[Token], decl: usize) -> Option<String> {
    let start = statement_start(toks, decl);
    let window = &toks[start..decl];
    if window.iter().any(|t| t.is_ident("let")) {
        let at = window.iter().position(|t| t.is_ident("let"))?;
        let mut idents = window[at + 1..].iter().filter_map(|t| t.ident());
        let mut first = idents.next()?;
        while matches!(first, "mut" | "ref") {
            first = idents.next()?;
        }
        if first.starts_with(char::is_uppercase) {
            first = idents.next()?; // pattern ctor like `Ok(x)`
        }
        return Some(first.to_string());
    }
    // struct-literal field: nearest single `:` preceded by an ident
    for k in (start..decl).rev() {
        if toks[k].is_punct(':')
            && !toks.get(k + 1).is_some_and(|t| t.is_punct(':'))
            && k > 0
            && !toks[k - 1].is_punct(':')
        {
            if let Some(name) = toks[k - 1].ident() {
                return Some(name.to_string());
            }
        }
    }
    None
}

/// Index of the first token of the statement containing `at`: one past
/// the nearest `;`, `{`, or `}` before it.
fn statement_start(toks: &[Token], at: usize) -> usize {
    let mut k = at;
    while k > 0 {
        k -= 1;
        if matches!(toks[k].tok, Tok::Punct(';') | Tok::Punct('{') | Tok::Punct('}')) {
            return k + 1;
        }
    }
    0
}

/// Index of the `)` matching the `(` at `open`.
fn matching_close(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut k = open;
    while k < toks.len() {
        match toks[k].tok {
            Tok::Punct('(') => depth += 1,
            Tok::Punct(')') => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
        k += 1;
    }
    toks.len() - 1
}

// ---- the scope walk ----

#[allow(clippy::too_many_arguments)]
fn scan_functions(
    file: &Path,
    rel: &str,
    toks: &[Token],
    table: &HashMap<String, String>,
    edges: &mut BTreeMap<(String, String), EdgeInfo>,
    violations: &mut Vec<Violation>,
    fn_defs: &mut BTreeMap<String, FnDef>,
    summaries: &mut Vec<FnSummary>,
) {
    let mut i = 0;
    while i < toks.len() {
        if !toks[i].is_ident("fn") {
            i += 1;
            continue;
        }
        let Some(name) = toks.get(i + 1).and_then(|t| t.ident()).map(|s| s.to_string()) else {
            i += 1; // `fn(..)` pointer type
            continue;
        };
        // find the body's opening brace; a `;` first means no body
        let mut j = i + 2;
        let mut open = None;
        while j < toks.len() {
            match toks[j].tok {
                Tok::Punct('{') => {
                    open = Some(j);
                    break;
                }
                Tok::Punct(';') => break,
                _ => j += 1,
            }
        }
        let Some(open) = open else {
            i = j + 1;
            continue;
        };
        let close = matching_brace(toks, open);
        let summary = walk_body(file, rel, toks, open + 1, close, table, edges, violations);
        let idx = summaries.len();
        summaries.push(summary);
        fn_defs
            .entry(name)
            .and_modify(|d| *d = FnDef::Ambiguous)
            .or_insert(FnDef::Unique(idx));
        i = open + 1; // keep scanning inside: nested fns are rare but real
    }
}

fn matching_brace(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut k = open;
    while k < toks.len() {
        match toks[k].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
        k += 1;
    }
    toks.len() - 1
}

#[allow(clippy::too_many_arguments)]
fn walk_body(
    file: &Path,
    rel: &str,
    toks: &[Token],
    start: usize,
    end: usize,
    table: &HashMap<String, String>,
    edges: &mut BTreeMap<(String, String), EdgeInfo>,
    violations: &mut Vec<Violation>,
) -> FnSummary {
    let mut summary =
        FnSummary { file: rel.to_string(), acquires: Vec::new(), calls_under_lock: Vec::new() };
    let mut holds: Vec<Hold> = Vec::new();
    let mut depth = 0usize;
    let mut j = start;
    while j < end {
        match &toks[j].tok {
            Tok::Punct('{') => {
                holds.retain(|h| h.binding.is_some());
                depth += 1;
            }
            Tok::Punct('}') => {
                depth = depth.saturating_sub(1);
                holds.retain(|h| h.binding.is_some() && h.depth <= depth);
            }
            Tok::Punct(';') => holds.retain(|h| h.binding.is_some()),
            Tok::Ident(name) => {
                // early release: drop(guard)
                if name == "drop"
                    && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
                    && toks.get(j + 3).is_some_and(|t| t.is_punct(')'))
                {
                    if let Some(g) = toks.get(j + 2).and_then(|t| t.ident()) {
                        if let Some(pos) =
                            holds.iter().rposition(|h| h.binding.as_deref() == Some(g))
                        {
                            holds.remove(pos);
                        }
                        j += 4;
                        continue;
                    }
                }

                // acquisition?
                if let Some((class, call_end)) = acquisition_at(toks, j, table) {
                    let line = toks[j].line;
                    for h in &holds {
                        if h.class != class {
                            edges.entry((h.class.clone(), class.clone())).or_insert_with(|| EdgeInfo {
                                from_file: rel.to_string(),
                                from_line: h.line,
                                to_file: rel.to_string(),
                                to_line: line,
                            });
                        }
                    }
                    summary.acquires.push((class.clone(), line));
                    let binding = guard_binding(toks, j, call_end);
                    holds.push(Hold { class, line, binding, depth });
                    j = call_end + 1;
                    continue;
                }

                let is_call = toks.get(j + 1).is_some_and(|t| t.is_punct('('));
                if is_call && WAIT_CALLS.contains(&name.as_str()) && toks.get(j.wrapping_sub(1)).is_some_and(|t| t.is_punct('.')) {
                    wait_check(file, rel, toks, j, &holds, violations);
                } else if is_call && !holds.is_empty() {
                    let is_file_io = BLOCKING_CALLS.contains(&name.as_str())
                        || (j >= 2
                            && toks[j - 1].is_punct(':')
                            && toks[j - 2].is_punct(':')
                            && toks.get(j.wrapping_sub(3)).is_some_and(|t| t.is_ident("fs") || t.is_ident("File")));
                    if is_file_io {
                        blocking_violation(file, rel, name, toks[j].line, &holds, None, violations);
                    } else if !COMPOSE_BLOCKLIST.contains(&name.as_str())
                        && !name.starts_with(char::is_uppercase)
                    {
                        let held: Vec<(String, usize)> =
                            holds.iter().map(|h| (h.class.clone(), h.line)).collect();
                        summary.calls_under_lock.push((held, name.clone()));
                    }
                }
            }
            _ => {}
        }
        j += 1;
    }
    summary
}

/// Is the token at `j` an acquisition of a classed lock? Returns the
/// class and the index of the call's closing `)`.
fn acquisition_at(
    toks: &[Token],
    j: usize,
    table: &HashMap<String, String>,
) -> Option<(String, usize)> {
    let name = toks[j].ident()?;
    if name == "lock_unpoisoned" && toks.get(j + 1).is_some_and(|t| t.is_punct('(')) {
        let close = matching_close(toks, j + 1);
        let receiver = toks[j + 2..close].iter().rev().find_map(|t| t.ident())?;
        let class = resolve(receiver, table)?;
        return Some((class, close));
    }
    if matches!(name, "lock" | "read" | "write")
        && j >= 2
        && toks[j - 1].is_punct('.')
        && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
        && toks.get(j + 2).is_some_and(|t| t.is_punct(')'))
    {
        // zero-arg only: `stream.write(buf)` is I/O, not an acquisition
        let receiver = match &toks[j - 2].tok {
            Tok::Ident(r) => r.clone(),
            Tok::Punct(']') => {
                // xs[i].lock(): resolve through the indexed collection
                let mut depth = 0usize;
                let mut k = j - 2;
                loop {
                    match toks[k].tok {
                        Tok::Punct(']') => depth += 1,
                        Tok::Punct('[') => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    if k == 0 {
                        return None;
                    }
                    k -= 1;
                }
                toks.get(k.checked_sub(1)?)?.ident()?.to_string()
            }
            _ => return None,
        };
        let class = resolve(&receiver, table)?;
        return Some((class, j + 2));
    }
    None
}

/// Resolve a receiver ident to a class: exact binding, then the plural
/// collection (`lane` -> field `lanes`).
fn resolve(receiver: &str, table: &HashMap<String, String>) -> Option<String> {
    if let Some(c) = table.get(receiver) {
        return Some(c.clone());
    }
    table.get(&format!("{receiver}s")).cloned()
}

/// Does the guard born at the call ending at `call_end` outlive its
/// statement, and under which binding? Let-bound only when chained
/// through nothing but unwrap-family adapters into a plain `let`.
fn guard_binding(toks: &[Token], acq: usize, call_end: usize) -> Option<String> {
    let start = statement_start(toks, acq);
    let window = &toks[start..acq];
    if window.iter().any(|t| matches!(t.ident(), Some("match" | "while" | "for" | "loop" | "return"))) {
        return None; // scrutinee/argument position: statement-scoped
    }
    if window.iter().any(|t| t.is_punct('*')) {
        return None; // `let x = *g.lock()...` binds a deref copy, not the guard
    }
    let let_at = window.iter().position(|t| t.is_ident("let"))?;
    // forward: only unwrap-family chaining keeps the guard
    let mut k = call_end + 1;
    loop {
        if toks.get(k).is_some_and(|t| t.is_punct('?')) {
            k += 1;
            continue;
        }
        if toks.get(k).is_some_and(|t| t.is_punct('.'))
            && toks.get(k + 1).is_some_and(|t| matches!(t.ident(), Some("unwrap" | "expect" | "unwrap_or_else")))
            && toks.get(k + 2).is_some_and(|t| t.is_punct('('))
        {
            k = matching_close(toks, k + 2) + 1;
            continue;
        }
        break;
    }
    if !toks.get(k).is_some_and(|t| t.is_punct(';') || t.is_punct('{')) {
        return None; // consumed by a further call / argument position
    }
    let mut idents = window[let_at + 1..].iter().filter_map(|t| t.ident());
    let mut first = idents.next()?;
    while matches!(first, "mut" | "ref") {
        first = idents.next()?;
    }
    if first.starts_with(char::is_uppercase) {
        first = idents.next()?;
    }
    Some(first.to_string())
}

/// A condvar wait may hold exactly the guard it re-parks (named in its
/// first argument); anything else held across the park is a violation.
fn wait_check(
    file: &Path,
    rel: &str,
    toks: &[Token],
    j: usize,
    holds: &[Hold],
    violations: &mut Vec<Violation>,
) {
    if holds.is_empty() {
        return;
    }
    let close = matching_close(toks, j + 1);
    let mut first_arg_end = close;
    let mut depth = 0usize;
    for k in j + 1..close {
        match toks[k].tok {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => depth = depth.saturating_sub(1),
            Tok::Punct(',') if depth == 1 => {
                first_arg_end = k;
                break;
            }
            _ => {}
        }
    }
    let waived = holds.iter().rposition(|h| {
        h.binding
            .as_deref()
            .is_some_and(|b| toks[j + 2..first_arg_end].iter().any(|t| t.is_ident(b)))
    });
    let name = toks[j].ident().unwrap_or("wait");
    blocking_violation(file, rel, name, toks[j].line, holds, waived, violations);
}

/// Flag every held class (minus an optional waived index) that is not
/// allowlisted for this file.
fn blocking_violation(
    file: &Path,
    rel: &str,
    call: &str,
    line: usize,
    holds: &[Hold],
    waived: Option<usize>,
    violations: &mut Vec<Violation>,
) {
    for (idx, h) in holds.iter().enumerate() {
        if Some(idx) == waived {
            continue;
        }
        if BLOCKING_ALLOWLIST.contains(&(rel, h.class.as_str())) {
            continue;
        }
        violations.push(Violation {
            file: file.to_path_buf(),
            line,
            message: format!(
                "blocking call `{call}` while holding lock class \"{}\" (acquired at {}:{}) — release the guard first or allowlist the audited pair",
                h.class, rel, h.line
            ),
        });
    }
}

/// One level of call composition: if a function acquires classes and is
/// defined exactly once in the tree, a call to it with guards held folds
/// held -> acquired edges.
fn compose_calls(
    fn_defs: &BTreeMap<String, FnDef>,
    summaries: &[FnSummary],
    edges: &mut BTreeMap<(String, String), EdgeInfo>,
) {
    for s in summaries {
        for (held, callee) in &s.calls_under_lock {
            let Some(FnDef::Unique(idx)) = fn_defs.get(callee) else { continue };
            let callee_summary = &summaries[*idx];
            for (class, to_line) in &callee_summary.acquires {
                for (held_class, from_line) in held {
                    if held_class != class {
                        edges.entry((held_class.clone(), class.clone())).or_insert_with(|| EdgeInfo {
                            from_file: s.file.clone(),
                            from_line: *from_line,
                            to_file: callee_summary.file.clone(),
                            to_line: *to_line,
                        });
                    }
                }
            }
        }
    }
}

/// Fail on any cycle in the folded class graph: a cycle is a lock-order
/// inversion some interleaving can deadlock on.
fn cycle_check(edges: &BTreeMap<(String, String), EdgeInfo>, violations: &mut Vec<Violation>) {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from.as_str()).or_default().push(to.as_str());
    }
    // DFS with an explicit stack; report the first cycle per start node.
    let mut done: BTreeSet<&str> = BTreeSet::new();
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for &root in &nodes {
        if done.contains(root) {
            continue;
        }
        let mut path: Vec<&str> = vec![root];
        let mut iters: Vec<usize> = vec![0];
        while let Some(&node) = path.last() {
            let i = *iters.last().expect("iter per node");
            let nexts = adj.get(node).map(Vec::as_slice).unwrap_or(&[]);
            if i >= nexts.len() {
                done.insert(node);
                path.pop();
                iters.pop();
                if let Some(last) = iters.last_mut() {
                    *last += 1;
                }
                continue;
            }
            let next = nexts[i];
            if let Some(at) = path.iter().position(|&n| n == next) {
                let cycle: Vec<&str> = path[at..].iter().copied().chain([next]).collect();
                let mut detail = String::new();
                for pair in cycle.windows(2) {
                    let info = &edges[&(pair[0].to_string(), pair[1].to_string())];
                    let _ = write!(
                        detail,
                        "\n  {} -> {} (held at {}, acquired at {})",
                        pair[0],
                        pair[1],
                        info.from_site(),
                        info.to_site()
                    );
                }
                let closing = &edges[&(cycle[cycle.len() - 2].to_string(), cycle[cycle.len() - 1].to_string())];
                violations.push(Violation {
                    file: PathBuf::from(closing.to_file.clone()),
                    line: closing.to_line,
                    message: format!("lock-order cycle: {}{detail}", cycle.join(" -> ")),
                });
                *iters.last_mut().expect("iter") += 1;
                continue;
            }
            if done.contains(next) {
                *iters.last_mut().expect("iter") += 1;
                continue;
            }
            path.push(next);
            iters.push(0);
        }
    }
}

/// Edges documented in a committed LOCKS.md (`| `a` | `b` | ...` rows).
fn documented_edges(locks_md: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for line in locks_md.lines() {
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // | `from` | `to` | provenance | — class rows have a kind cell instead
        if cells.len() >= 4
            && cells[1].starts_with('`')
            && cells[2].starts_with('`')
            && matches!(cells[3], "static" | "runtime" | "static+runtime")
        {
            let strip = |c: &str| c.trim_matches('`').to_string();
            out.push((strip(cells[1]), strip(cells[2])));
        }
    }
    out
}

/// The `locks` command: the three static rules, plus the contradiction
/// check against documented LOCKS.md edges.
pub fn locks() -> Result<()> {
    let root = repo_root();
    let mut analysis = analyze_tree(&root.join("rust").join("src"))?;
    if let Ok(locks_md) = std::fs::read_to_string(root.join("LOCKS.md")) {
        contradiction_check(&analysis.edges, &documented_edges(&locks_md), &mut analysis.violations);
    }
    if analysis.violations.is_empty() {
        println!(
            "xtask locks: clean ({} classes, {} static edges)",
            analysis.classes.len(),
            analysis.edges.len()
        );
        return Ok(());
    }
    let mut report = String::new();
    for v in &analysis.violations {
        let _ = writeln!(report, "{}:{}: {}", v.file.display(), v.line, v.message);
    }
    bail!("xtask locks: {} violation(s)\n{report}", analysis.violations.len());
}

/// An edge whose reverse is documented (and which is not itself
/// documented) contradicts the committed hierarchy even before it closes
/// a full static cycle.
fn contradiction_check(
    edges: &BTreeMap<(String, String), EdgeInfo>,
    documented: &[(String, String)],
    violations: &mut Vec<Violation>,
) {
    for ((from, to), info) in edges {
        let reversed = documented.iter().any(|(a, b)| a == to && b == from);
        let forward = documented.iter().any(|(a, b)| a == from && b == to);
        if reversed && !forward {
            violations.push(Violation {
                file: PathBuf::from(info.to_file.clone()),
                line: info.to_line,
                message: format!(
                    "lock-order contradiction: acquires \"{to}\" while holding \"{from}\" (at {}), but LOCKS.md documents \"{to}\" -> \"{from}\"",
                    info.to_site()
                ),
            });
        }
    }
}

// ---- lockgraph: merged artifacts + freshness gate ----

/// A provenance-tagged merged edge (static pass ∪ runtime witness).
struct MergedEdge {
    provenance: &'static str,
    from_site: String,
    to_site: String,
}

/// `cargo xtask lockgraph [--check]`: regenerate `LOCKS.md` and
/// `rust/artifacts/lockgraph.dot` from the static graph merged with the
/// runtime witness's observations over a representative workload. With
/// `--check`, compare against the committed bytes instead of writing.
pub fn lockgraph(check: bool) -> Result<()> {
    let root = repo_root();
    let analysis = analyze_tree(&root.join("rust").join("src"))?;
    if !analysis.violations.is_empty() {
        let mut report = String::new();
        for v in &analysis.violations {
            let _ = writeln!(report, "{}:{}: {}", v.file.display(), v.line, v.message);
        }
        bail!("xtask lockgraph: static pass found {} violation(s); fix before regenerating\n{report}", analysis.violations.len());
    }

    let mut merged: BTreeMap<(String, String), MergedEdge> = BTreeMap::new();
    for ((from, to), info) in &analysis.edges {
        merged.insert((from.clone(), to.clone()), MergedEdge {
            provenance: "static",
            from_site: info.from_site(),
            to_site: info.to_site(),
        });
    }
    for edge in runtime_edges()? {
        match merged.get_mut(&(edge.from.to_string(), edge.to.to_string())) {
            Some(m) => m.provenance = "static+runtime",
            None => {
                merged.insert((edge.from.to_string(), edge.to.to_string()), MergedEdge {
                    provenance: "runtime",
                    from_site: edge.from_site,
                    to_site: edge.to_site,
                });
            }
        }
    }

    let locks_md = render_locks_md(&analysis.classes, &merged);
    let dot = render_dot(&analysis.classes, &merged);
    let locks_path = root.join("LOCKS.md");
    let dot_path = root.join("rust").join("artifacts").join("lockgraph.dot");
    if check {
        let mut stale = Vec::new();
        if std::fs::read_to_string(&locks_path).ok().as_deref() != Some(locks_md.as_str()) {
            stale.push("LOCKS.md");
        }
        if std::fs::read_to_string(&dot_path).ok().as_deref() != Some(dot.as_str()) {
            stale.push("rust/artifacts/lockgraph.dot");
        }
        if !stale.is_empty() {
            bail!(
                "xtask lockgraph --check: {} out of date with the tree — run `cargo xtask lockgraph` and commit the result",
                stale.join(" and ")
            );
        }
        println!(
            "xtask lockgraph --check: fresh ({} classes, {} edges)",
            analysis.classes.len(),
            merged.len()
        );
        return Ok(());
    }
    std::fs::create_dir_all(dot_path.parent().expect("artifacts dir"))?;
    std::fs::write(&locks_path, &locks_md).with_context(|| format!("writing {}", locks_path.display()))?;
    std::fs::write(&dot_path, &dot).with_context(|| format!("writing {}", dot_path.display()))?;
    println!(
        "xtask lockgraph: wrote LOCKS.md and rust/artifacts/lockgraph.dot ({} classes, {} edges)",
        analysis.classes.len(),
        merged.len()
    );
    Ok(())
}

fn render_locks_md(classes: &[ClassDecl], edges: &BTreeMap<(String, String), MergedEdge>) -> String {
    let mut out = String::new();
    out.push_str("# Lock-discipline hierarchy\n\n");
    out.push_str("Generated by `cargo xtask lockgraph` — do not edit by hand. CI runs\n");
    out.push_str("`cargo xtask lockgraph --check` and fails when this file or\n");
    out.push_str("`rust/artifacts/lockgraph.dot` drifts from the tree. The graph is the\n");
    out.push_str("union of the static lock-order pass (`cargo xtask locks`) and the\n");
    out.push_str("runtime lockdep witness (`gbf::infra::lockdep`, debug builds) over the\n");
    out.push_str("lockgraph workload.\n\n");
    out.push_str("## Lock classes\n\n");
    out.push_str("| class | kind | declared in |\n");
    out.push_str("|---|---|---|\n");
    for c in classes {
        let _ = writeln!(out, "| `{}` | {} | `rust/src/{}` |", c.class, c.kind, c.file);
    }
    out.push_str("\n## Class-order edges\n\n");
    out.push_str("`a -> b` means some code path acquires class `b` while holding class\n");
    out.push_str("`a`. Cycles here are potential deadlocks; both the static pass and the\n");
    out.push_str("runtime witness fail on the first one they see.\n\n");
    if edges.is_empty() {
        out.push_str("No edges: every classed guard in the tree is released before the next\n");
        out.push_str("class is acquired, and the analyzer keeps it that way.\n");
        return out;
    }
    out.push_str("| held | acquiring | seen by | sites |\n");
    out.push_str("|---|---|---|---|\n");
    for ((from, to), m) in edges {
        let _ = writeln!(
            out,
            "| `{from}` | `{to}` | {} | `{}` -> `{}` |",
            m.provenance, m.from_site, m.to_site
        );
    }
    out
}

fn render_dot(classes: &[ClassDecl], edges: &BTreeMap<(String, String), MergedEdge>) -> String {
    let mut out = String::new();
    out.push_str("// Generated by `cargo xtask lockgraph` — do not edit by hand.\n");
    out.push_str("// Nodes are lock classes (ellipses are condvars); an edge a -> b means\n");
    out.push_str("// some code path acquires b while holding a.\n");
    out.push_str("digraph lock_order {\n");
    out.push_str("  rankdir=LR;\n");
    out.push_str("  node [shape=box, fontname=\"monospace\"];\n");
    for c in classes {
        if c.kind == "condvar" {
            let _ = writeln!(out, "  \"{}\" [shape=ellipse];", c.class);
        } else {
            let _ = writeln!(out, "  \"{}\";", c.class);
        }
    }
    for ((from, to), m) in edges {
        let _ = writeln!(out, "  \"{from}\" -> \"{to}\" [label=\"{}\"];", m.provenance);
    }
    out.push_str("}\n");
    out
}

// ---- runtime witness leg ----

/// Drive a representative workload through the public service API so the
/// lockdep witness observes real nesting, then drain its edges. In a
/// release build (`is_active() == false`) the witness is compiled out and
/// this contributes nothing — the dev-profile CI job is the one that
/// feeds runtime edges into the artifacts.
fn runtime_edges() -> Result<Vec<gbf::infra::lockdep::ObservedEdge>> {
    if !gbf::infra::lockdep::is_active() {
        eprintln!("xtask lockgraph: release build, lockdep witness inactive — static edges only");
        return Ok(Vec::new());
    }
    runtime_workload()?;
    Ok(gbf::infra::lockdep::observed_edges())
}

fn runtime_workload() -> Result<()> {
    use gbf::coordinator::{FilterService, FilterSpec, RemoteFilterService, WireServer};
    use gbf::filter::params::FilterConfig;
    use std::sync::Arc;

    let err = |e: gbf::coordinator::GbfError| anyhow::anyhow!("lockgraph workload: {e}");
    let service = Arc::new(FilterService::new());
    let cfg = FilterConfig { log2_m_words: 12, ..Default::default() };
    let mut spec = FilterSpec::new(cfg, 4);
    spec.policy.max_batch = 256;
    spec.max_queue_depth = Some(1 << 14);
    let keys: Vec<u64> = (1..=2048u64).map(|k| k.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1).collect();

    // local service: create, bulk add/query, snapshot/restore round trip
    let handle = service.create_filter_spec("lockgraph_local", spec).map_err(err)?;
    handle.add_bulk(&keys).wait().map_err(err)?;
    let hits = handle.query_bulk(&keys).wait().map_err(err)?;
    if hits.iter().any(|h| !h) {
        bail!("lockgraph workload: bloom false negative");
    }
    let _ = service.stats("lockgraph_local").map_err(err)?;
    let dir = std::env::temp_dir().join(format!("gbf-lockgraph-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let snap = dir.join("local");
    service.snapshot("lockgraph_local", &snap).map_err(err)?;
    service.drop_filter("lockgraph_local").map_err(err)?;
    let restored = service.restore("lockgraph_local", &snap).map_err(err)?;
    let hits = restored.query_bulk(&keys).wait().map_err(err)?;
    if hits.iter().any(|h| !h) {
        bail!("lockgraph workload: restore lost keys");
    }

    // wire transport: the same shapes through server + client threads
    let server = WireServer::bind(Arc::clone(&service), "127.0.0.1:0")?;
    let client = RemoteFilterService::connect(server.local_addr())?;
    let remote = client
        .create_filter("lockgraph_remote", FilterConfig { log2_m_words: 10, ..Default::default() }, 2)
        .map_err(err)?;
    remote.add_bulk(&keys[..256]).wait().map_err(err)?;
    let hits = remote.query_bulk(&keys[..256]).wait().map_err(err)?;
    if hits.iter().any(|h| !h) {
        bail!("lockgraph workload: remote bloom false negative");
    }
    let remote_snap = dir.join("remote");
    let remote_snap_str =
        remote_snap.to_str().ok_or_else(|| anyhow::anyhow!("non-UTF8 temp dir"))?.to_string();
    client.snapshot("lockgraph_remote", &remote_snap_str).map_err(err)?;
    client.drop_filter("lockgraph_remote").map_err(err)?;
    let restored = client.restore("lockgraph_remote", &remote_snap_str).map_err(err)?;
    let hits = restored.query_bulk(&keys[..256]).wait().map_err(err)?;
    if hits.iter().any(|h| !h) {
        bail!("lockgraph workload: remote restore lost keys");
    }
    client.drop_filter("lockgraph_remote").map_err(err)?;
    drop(client);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The committed tree must satisfy its own lock discipline — the
    /// unit-test mirror of the CI `cargo xtask locks` gate.
    #[test]
    fn repo_is_lock_discipline_clean() {
        let src = repo_root().join("rust").join("src");
        let analysis = analyze_tree(&src).expect("analysis runs");
        let report: Vec<String> = analysis
            .violations
            .iter()
            .map(|v| format!("{}:{}: {}", v.file.display(), v.line, v.message))
            .collect();
        assert!(analysis.violations.is_empty(), "lock-discipline violations:\n{}", report.join("\n"));
        assert!(
            analysis.classes.iter().any(|c| c.class == "batcher.queue"),
            "class inventory lost the batcher: {:?}",
            analysis.classes
        );
        assert!(
            analysis.classes.iter().any(|c| c.class == "service.catalog" && c.kind == "rwlock"),
            "catalog rwlock missing from inventory"
        );
    }

    fn fixture(dir: &Path, name: &str, body: &str) {
        std::fs::create_dir_all(dir).expect("mkdir");
        std::fs::write(dir.join(name), body).expect("write fixture");
    }

    /// A deliberately inverted pair must be caught by the static pass —
    /// the same inversion `lockdep_witness.rs` proves the runtime witness
    /// catches.
    #[test]
    fn static_pass_catches_seeded_inversion() {
        let dir = std::env::temp_dir().join(format!("gbf-xtask-locks-inv-{}", std::process::id()));
        fixture(
            &dir,
            "inverted.rs",
            r#"
struct X { a: Mutex<u32>, b: Mutex<u32> }
impl X {
    fn new() -> X {
        X { a: Mutex::new_class("fix.a", 0), b: Mutex::new_class("fix.b", 0) }
    }
    fn ab(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }
    fn ba(&self) -> u32 {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        *ga + *gb
    }
}
"#,
        );
        let analysis = analyze_tree(&dir).expect("analysis runs");
        assert!(
            analysis.edges.contains_key(&("fix.a".into(), "fix.b".into()))
                && analysis.edges.contains_key(&("fix.b".into(), "fix.a".into())),
            "both nesting directions must fold edges: {:?}",
            analysis.edges.keys().collect::<Vec<_>>()
        );
        let cycles: Vec<&Violation> =
            analysis.violations.iter().filter(|v| v.message.contains("lock-order cycle")).collect();
        assert!(!cycles.is_empty(), "inversion must be a cycle violation: {:?}", analysis.violations);
        assert!(
            cycles.iter().any(|v| v.message.contains("fix.a") && v.message.contains("fix.b")),
            "cycle message names both classes: {:?}",
            cycles
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// One level of call composition: lock a, call a unique helper that
    /// locks b — still an a -> b edge.
    #[test]
    fn composition_folds_callee_acquisitions() {
        let dir = std::env::temp_dir().join(format!("gbf-xtask-locks-comp-{}", std::process::id()));
        fixture(
            &dir,
            "composed.rs",
            r#"
struct Y { a: Mutex<u32>, b: Mutex<u32> }
fn outer(y: &Y) -> u32 {
    let ga = y.a.lock().unwrap();
    helper_locks_b(y) + *ga
}
fn helper_locks_b(y: &Y) -> u32 {
    let gb = y.b.lock().unwrap();
    *gb
}
fn decl() -> Y {
    Y { a: Mutex::new_class("comp.a", 0), b: Mutex::new_class("comp.b", 0) }
}
"#,
        );
        let analysis = analyze_tree(&dir).expect("analysis runs");
        assert!(
            analysis.edges.contains_key(&("comp.a".into(), "comp.b".into())),
            "composed edge missing: {:?}",
            analysis.edges.keys().collect::<Vec<_>>()
        );
        assert!(analysis.violations.is_empty(), "a one-way nesting is not a violation: {:?}", analysis.violations);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Guard-scope model: statement temporaries and `drop(guard)` end the
    /// hold, so sequential (not nested) acquisitions fold no edge.
    #[test]
    fn released_guards_fold_no_edges() {
        let dir = std::env::temp_dir().join(format!("gbf-xtask-locks-rel-{}", std::process::id()));
        fixture(
            &dir,
            "released.rs",
            r#"
struct Z { a: Mutex<u32>, b: Mutex<u32> }
fn sequential(z: &Z) -> u32 {
    let x = *z.a.lock().unwrap();
    let y = *z.b.lock().unwrap();
    x + y
}
fn dropped(z: &Z) -> u32 {
    let ga = z.a.lock().unwrap();
    let x = *ga;
    drop(ga);
    let gb = z.b.lock().unwrap();
    x + *gb
}
fn decl() -> Z {
    Z { a: Mutex::new_class("rel.a", 0), b: Mutex::new_class("rel.b", 0) }
}
"#,
        );
        let analysis = analyze_tree(&dir).expect("analysis runs");
        assert!(analysis.edges.is_empty(), "sequential locking folded edges: {:?}", analysis.edges.keys().collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blocking_under_lock_and_wait_waiver() {
        let dir = std::env::temp_dir().join(format!("gbf-xtask-locks-blk-{}", std::process::id()));
        fixture(
            &dir,
            "blocking.rs",
            r#"
struct W { a: Mutex<u32>, q: Mutex<u32>, cv: Condvar }
fn bad_io(w: &W) {
    let ga = w.a.lock().unwrap();
    let _text = std::fs::read_to_string("f").unwrap();
    let _ = *ga;
}
fn good_wait(w: &W) {
    let mut q = w.q.lock().unwrap();
    q = w.cv.wait(q).unwrap();
    let _ = *q;
}
fn bad_wait(w: &W) {
    let ga = w.a.lock().unwrap();
    let mut q = w.q.lock().unwrap();
    q = w.cv.wait(q).unwrap();
    let _ = *ga + *q;
}
fn decl() -> W {
    W {
        a: Mutex::new_class("blk.a", 0),
        q: Mutex::new_class("blk.q", 0),
        cv: Condvar::new_class("blk.cv"),
    }
}
"#,
        );
        let analysis = analyze_tree(&dir).expect("analysis runs");
        let blocking: Vec<&Violation> =
            analysis.violations.iter().filter(|v| v.message.contains("blocking call")).collect();
        assert!(
            blocking.iter().any(|v| v.message.contains("read_to_string") && v.message.contains("blk.a")),
            "file I/O under blk.a must be flagged: {:?}",
            analysis.violations
        );
        assert!(
            blocking.iter().any(|v| v.message.contains("`wait`") && v.message.contains("blk.a")),
            "wait holding a second class must be flagged: {:?}",
            analysis.violations
        );
        assert!(
            !blocking.iter().any(|v| v.message.contains("blk.q")),
            "the re-parked guard is waived: {:?}",
            blocking
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sync_shim_rule_scopes() {
        let dir = std::env::temp_dir().join(format!("gbf-xtask-locks-shim-{}", std::process::id()));
        fixture(
            &dir.join("coordinator"),
            "direct.rs",
            "use std::sync::Mutex;\nuse std::sync::{Arc, atomic::AtomicU64};\n",
        );
        fixture(&dir.join("infra"), "shim.rs", "use std::sync::{Condvar, Mutex, RwLock};\n");
        let analysis = analyze_tree(&dir).expect("analysis runs");
        let shim: Vec<&Violation> =
            analysis.violations.iter().filter(|v| v.message.contains("std::sync")).collect();
        assert_eq!(shim.len(), 2, "Mutex + atomic flagged, Arc and infra/ exempt: {:?}", analysis.violations);
        assert!(shim.iter().all(|v| v.file.ends_with("direct.rs")));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn contradiction_against_documented_edges() {
        let mut edges = BTreeMap::new();
        edges.insert(("b".to_string(), "a".to_string()), EdgeInfo {
            from_file: "x.rs".into(),
            from_line: 3,
            to_file: "x.rs".into(),
            to_line: 4,
        });
        let documented = documented_edges("| `a` | `b` | static | `x.rs:1` -> `x.rs:2` |\n");
        assert_eq!(documented, [("a".to_string(), "b".to_string())]);
        let mut violations = Vec::new();
        contradiction_check(&edges, &documented, &mut violations);
        assert_eq!(violations.len(), 1, "{violations:?}");
        assert!(violations[0].message.contains("contradiction"));
    }
}
