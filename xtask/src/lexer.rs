//! A minimal Rust lexer for the lock-discipline passes (`locks.rs`).
//!
//! This is deliberately *not* a full Rust lexer: the analyzer only needs
//! identifiers, string literals, and punctuation, each tagged with a line
//! number. Everything else — comments (line and nested block), char
//! literals, lifetimes, numeric literals, raw/byte strings — is consumed
//! and dropped so it can never masquerade as code. The token patterns the
//! analyzer matches (`Mutex::new_class("...")`, `.lock()`,
//! `lock_unpoisoned(&x)`, `drop(guard)`, `std :: sync :: Mutex`) are all
//! expressible over this trio.

/// One lexed token. Multi-char operators arrive as consecutive
/// single-char `Punct`s (`::` is `Punct(':') Punct(':')`); the analyzer
/// matches the pairs it cares about.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    /// The raw contents between the quotes (escapes left as-is; lock
    /// class names never contain any).
    Str(String),
    Punct(char),
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    /// 1-based source line of the token's first character.
    pub line: usize,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn str_lit(&self) -> Option<&str> {
        match &self.tok {
            Tok::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }

    pub fn is_ident(&self, name: &str) -> bool {
        self.ident() == Some(name)
    }
}

pub fn lex(text: &str) -> Vec<Token> {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < chars.len() {
        let c = chars[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if chars.get(i + 1) == Some(&'/') => {
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                }
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                // block comments nest in Rust
                let mut depth = 1;
                i += 2;
                while i < chars.len() && depth > 0 {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        if chars[i] == '\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
            }
            '"' => {
                let (tok, ni, nl) = scan_string(&chars, i, line);
                out.push(Token { tok, line });
                i = ni;
                line = nl;
            }
            '\'' => {
                // Lifetime (`'a`) vs char literal (`'a'`, `'\n'`, `'"'`).
                let next = chars.get(i + 1).copied();
                let after = chars.get(i + 2).copied();
                let is_lifetime = matches!(next, Some(n) if n.is_alphabetic() || n == '_') && after != Some('\'');
                if is_lifetime {
                    i += 1;
                    while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                        i += 1;
                    }
                } else {
                    // char literal: consume to the closing quote, honoring
                    // one escape
                    i += 1;
                    if chars.get(i) == Some(&'\\') {
                        i += 2;
                    } else {
                        i += 1;
                    }
                    if chars.get(i) == Some(&'\'') {
                        i += 1;
                    }
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                let word: String = chars[start..i].iter().collect();
                // raw / byte string prefixes: r"..", r#".."#, b"..", br".."
                if matches!(word.as_str(), "r" | "br" | "rb") {
                    let mut j = i;
                    let mut hashes = 0;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        let (tok, ni, nl) = scan_raw_string(&chars, j + 1, line, hashes);
                        out.push(Token { tok, line });
                        i = ni;
                        line = nl;
                        continue;
                    }
                    // not a raw string (e.g. raw identifier `r#match`):
                    // fall through and emit the word
                }
                if word == "b" && chars.get(i) == Some(&'"') {
                    let (tok, ni, nl) = scan_string(&chars, i, line);
                    out.push(Token { tok, line });
                    i = ni;
                    line = nl;
                    continue;
                }
                out.push(Token { tok: Tok::Ident(word), line });
            }
            c if c.is_ascii_digit() => {
                // numeric literal (incl. hex, suffixes, floats): drop it
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                if chars.get(i) == Some(&'.') && chars.get(i + 1).is_some_and(|d| d.is_ascii_digit()) {
                    i += 1;
                    while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                        i += 1;
                    }
                }
            }
            c => {
                out.push(Token { tok: Tok::Punct(c), line });
                i += 1;
            }
        }
    }
    out
}

/// Scan a normal (escape-honoring) string starting at the opening quote.
/// Returns the token, the index past the closing quote, and the new line.
fn scan_string(chars: &[char], open: usize, mut line: usize) -> (Tok, usize, usize) {
    let mut i = open + 1;
    let mut s = String::new();
    while i < chars.len() {
        match chars[i] {
            '\\' => {
                s.push(chars[i]);
                if let Some(&e) = chars.get(i + 1) {
                    if e == '\n' {
                        line += 1;
                    }
                    s.push(e);
                }
                i += 2;
            }
            '"' => {
                i += 1;
                break;
            }
            c => {
                if c == '\n' {
                    line += 1;
                }
                s.push(c);
                i += 1;
            }
        }
    }
    (Tok::Str(s), i, line)
}

/// Scan a raw string body starting just past the opening quote; ends at
/// `"` followed by `hashes` `#`s. No escapes.
fn scan_raw_string(chars: &[char], start: usize, mut line: usize, hashes: usize) -> (Tok, usize, usize) {
    let mut i = start;
    let mut s = String::new();
    while i < chars.len() {
        if chars[i] == '"' && (1..=hashes).all(|k| chars.get(i + k) == Some(&'#')) {
            i += 1 + hashes;
            break;
        }
        if chars[i] == '\n' {
            line += 1;
        }
        s.push(chars[i]);
        i += 1;
    }
    (Tok::Str(s), i, line)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src).into_iter().filter_map(|t| t.ident().map(|s| s.to_string())).collect()
    }

    #[test]
    fn lexes_the_patterns_the_analyzer_matches() {
        let toks = lex(r#"let g = self.queue.lock().unwrap(); Mutex::new_class("a.b", 0)"#);
        let strs: Vec<_> = toks.iter().filter_map(|t| t.str_lit()).collect();
        assert_eq!(strs, ["a.b"]);
        let ids = idents(r#"let g = self.queue.lock().unwrap();"#);
        assert_eq!(ids, ["let", "g", "self", "queue", "lock", "unwrap"]);
    }

    #[test]
    fn comments_strings_chars_and_lifetimes_never_leak_tokens() {
        assert_eq!(idents("// lock() in a comment\nx"), ["x"]);
        assert_eq!(idents("/* outer /* nested lock() */ still comment */ y"), ["y"]);
        // the lifetime `'static` is consumed silently, like the char literal
        assert_eq!(idents("let c = '\\''; let l: &'static str = \"lock()\"; z"), [
            "let", "c", "let", "l", "str", "z"
        ]);
        // a string containing an escaped quote must not swallow the rest
        assert_eq!(idents(r#"let s = "he said \"hi\""; after"#), ["let", "s", "after"]);
    }

    #[test]
    fn raw_and_byte_strings_are_single_tokens() {
        let toks = lex(r##"let s = r#"lock() "inner" quotes"#; tail"##);
        let strs: Vec<_> = toks.iter().filter_map(|t| t.str_lit()).collect();
        assert_eq!(strs, [r#"lock() "inner" quotes"#]);
        assert!(toks.iter().any(|t| t.is_ident("tail")));
        let toks = lex(r#"let b = b"bytes lock()"; tail"#);
        assert!(toks.iter().any(|t| t.str_lit() == Some("bytes lock()")));
        assert!(toks.iter().any(|t| t.is_ident("tail")));
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "a\n\"two\nline\"\nb /* c\nd */ e";
        let toks = lex(src);
        let find = |name: &str| toks.iter().find(|t| t.is_ident(name)).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 4);
        assert_eq!(find("e"), 5);
    }

    #[test]
    fn numbers_are_dropped_not_merged() {
        let toks = lex("foo(0xDEAD_BEEFu64, 1.5, 2)");
        let ids = toks.iter().filter_map(|t| t.ident()).collect::<Vec<_>>();
        assert_eq!(ids, ["foo"]);
    }
}
